//! The `wilkins worker` process mode: one member of a worker pool.
//!
//! A worker connects back to the coordinator that spawned it, binds a
//! peer-mesh listener, introduces itself, and then serves commands
//! until `Shutdown`:
//!
//! * `LaunchWorld` — join a distributed workflow: rebuild the graph
//!   from the shipped YAML, build the socket mesh, and run exactly the
//!   global ranks the owner map assigns here via
//!   `Wilkins::run_hosted`. Task codes, `lowfive::Vol`, flow control
//!   and collectives run unmodified — they only ever see `Comm`s.
//! * `RunInstance` — run one whole ensemble instance single-process
//!   inside this worker (the `process-per-instance` placement) and
//!   ship back the `RunReport` plus spans.
//!
//! Liveness: the process's transport I/O thread (the crate-private
//! `net::io` module) owns
//! the control link — it reads inbound command frames off the
//! nonblocking socket and forwards them to the serve loop over a
//! channel, and a poller timer stages [`proto::Heartbeat`] frames
//! every `heartbeat` interval (sharing the link's staging
//! `FrameWriter` with command replies, so writers can never
//! interleave mid-frame). The coordinator can therefore tell a busy
//! worker from a dead one. Each beat piggybacks a `K_TELEMETRY`
//! frame — a cumulative snapshot of the process-global counters plus
//! a clock sample — so the coordinator's live telemetry survives a
//! worker dying mid-run. The serve loop also consults
//! the process's [`FaultPlan`] on every `RunInstance` and
//! `LaunchWorld` (`at=launch` directives) — a no-op unless
//! `WILKINS_FAULT` armed it (tests and chaos smokes only).
//!
//! Workers deliberately hold their distributed world open until the
//! coordinator's `Shutdown`: our ranks finishing does not mean our
//! peers are done reading from us.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::coordinator::Wilkins;
use crate::ensemble::EnsembleSpec;
use crate::error::{Result, WilkinsError};
use crate::obs::{wiretap, Clock};
use crate::tasks::builtin_registry;

use super::faults::{FaultKind, FaultPlan};
use super::io::{ControlBeat, ControlEvent, FrameWriter, IoRt, Sink};
use super::proto::{
    self, InstanceDone, LaunchWorld, RankOutcomeWire, RunInstance, WorldDone,
};
use super::rendezvous;

/// How a worker process conducts itself: beat cadence + fault plan.
pub struct WorkerOpts {
    /// Control-socket heartbeat period; zero disables beating.
    pub heartbeat: Duration,
    /// Fault-injection schedule (empty in production).
    pub faults: FaultPlan,
}

impl WorkerOpts {
    /// The environment's prescription: `WILKINS_FAULT` for the plan
    /// (almost always empty), the pool's default cadence for beats.
    pub fn from_env() -> Result<WorkerOpts> {
        Ok(WorkerOpts {
            heartbeat: super::pool::HeartbeatConfig::default().interval,
            faults: FaultPlan::from_env()?,
        })
    }
}

/// Entry point behind `wilkins worker --connect ADDR --id K`. Also
/// callable from any other binary built on this crate (the benches
/// re-enter here so a bench executable can serve as its own pool).
pub fn worker_main(coordinator_addr: &str, worker_id: usize) -> Result<()> {
    worker_main_with(coordinator_addr, worker_id, WorkerOpts::from_env()?)
}

/// [`worker_main`] with explicit options — the CLI passes the
/// coordinator's `--heartbeat-ms` through here, and the fault tests
/// run emulated workers on threads with hand-built plans.
pub fn worker_main_with(
    coordinator_addr: &str,
    worker_id: usize,
    opts: WorkerOpts,
) -> Result<()> {
    let peer_listener = TcpListener::bind("127.0.0.1:0")
        .map_err(|e| WilkinsError::Comm(format!("bind peer listener: {e}")))?;
    let peer_addr = peer_listener
        .local_addr()
        .map_err(|e| WilkinsError::Comm(format!("peer local_addr: {e}")))?
        .to_string();
    let control = rendezvous::join(coordinator_addr, worker_id, &peer_addr)?;
    let faults = Arc::new(opts.faults);
    // The worker's run-relative clock: every telemetry sample and
    // every span shipped back is stamped against this one origin, so
    // the coordinator can align them with a single offset estimate.
    let clock = Clock::new();

    // The I/O thread owns the control link's read half; replies and
    // heartbeats share the write half through one staging FrameWriter
    // so concurrent writers can never interleave mid-frame. Command
    // frames come back to the serve loop over a channel.
    let io = IoRt::spawn()?;
    let read_half = control
        .try_clone()
        .map_err(|e| WilkinsError::Comm(format!("clone control stream: {e}")))?;
    let writer = FrameWriter::new(control, io.downgrade());
    let (tx, rx) = mpsc::channel();
    io.add_link(
        read_half,
        Sink::Control { events: tx },
        wiretap::LINK_UNSET,
        None,
        Some(Arc::clone(&writer)),
    );
    // The control beat (heartbeat + telemetry every interval) is a
    // poller timer, not a thread; it stops on its own once a fired
    // fault silences the worker or the link dies.
    if !opts.heartbeat.is_zero() {
        io.add_control_beat(ControlBeat {
            writer: Arc::clone(&writer),
            worker_id: worker_id as u64,
            interval: opts.heartbeat,
            faults: Arc::clone(&faults),
            clock,
        });
    }

    serve_loop(&rx, &writer, worker_id, &peer_listener, &faults, clock, &io)
    // `io` drops here: the last handle stops, wakes and joins the I/O
    // thread (flushing any staged reply bytes first).
}

fn serve_loop(
    rx: &mpsc::Receiver<ControlEvent>,
    writer: &Arc<FrameWriter>,
    worker_id: usize,
    peer_listener: &TcpListener,
    faults: &Arc<FaultPlan>,
    clock: Clock,
    io: &IoRt,
) -> Result<()> {
    // A worker that served a LaunchWorld keeps the mesh world alive
    // until shutdown (peers may still drain our streams).
    let mut held: Option<rendezvous::MeshWorld> = None;

    loop {
        let frame = match rx.recv() {
            // Channel gone = the I/O thread exited; treat like EOF.
            Err(mpsc::RecvError) => break,
            // Clean EOF at a frame boundary: coordinator went away.
            Ok(ControlEvent::Closed(None)) => break,
            // The control stream died mid-frame.
            Ok(ControlEvent::Closed(Some(e))) => return Err(WilkinsError::Comm(e)),
            Ok(ControlEvent::Frame((kind, payload))) => Some((kind, payload)),
        };
        match frame {
            None | Some((proto::K_SHUTDOWN, _)) => break,
            Some((proto::K_LAUNCH_WORLD, body)) => {
                let msg = LaunchWorld::decode(&body)?;
                match faults.on_launch_world(worker_id) {
                    Some(FaultKind::Kill) => {
                        if std::env::var("WILKINS_FAULT_HARD").as_deref() == Ok("1") {
                            std::process::exit(9);
                        }
                        faults.silence();
                        writer.shutdown_both();
                        return Ok(());
                    }
                    Some(FaultKind::Wedge) => park_forever(),
                    Some(FaultKind::Delay(ms)) => {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    // The reply-shaped faults have no meaning at this
                    // seam (a world has exactly one reply): serve
                    // normally.
                    Some(FaultKind::DupDone) | Some(FaultKind::DropDone) | None => {}
                }
                let reply = match serve_world(io, worker_id, peer_listener, &msg, clock) {
                    Ok((done, mesh)) => {
                        held = Some(mesh);
                        done
                    }
                    Err(e) => WorldDone { error: e.to_string(), ..WorldDone::default() },
                };
                send_reply(writer, proto::K_WORLD_DONE, &reply.encode())?;
            }
            Some((proto::K_RUN_INSTANCE, body)) => {
                let msg = RunInstance::decode(&body)?;
                let fired = faults.on_run_instance(worker_id);
                match fired {
                    Some(FaultKind::Kill) => {
                        if std::env::var("WILKINS_FAULT_HARD").as_deref() == Ok("1") {
                            std::process::exit(9);
                        }
                        // Emulated kill (threaded workers): vanish
                        // abruptly — close the control socket with no
                        // goodbye and stop beating.
                        faults.silence();
                        writer.shutdown_both();
                        return Ok(());
                    }
                    Some(FaultKind::Wedge) => {
                        // Alive but unresponsive: the case plain EOF
                        // detection can never catch.
                        park_forever();
                    }
                    Some(FaultKind::Delay(ms)) => {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    Some(FaultKind::DupDone) | Some(FaultKind::DropDone) | None => {}
                }
                let reply = match serve_instance(&msg) {
                    Ok(done) => done,
                    Err(e) => InstanceDone {
                        error: e.to_string(),
                        report: None,
                        spans: Vec::new(),
                        idem_key: msg.idem_key,
                    },
                };
                match fired {
                    Some(FaultKind::DropDone) => {
                        // Work done, acknowledgement lost — then go
                        // silent so the coordinator re-dispatches.
                        park_forever();
                    }
                    Some(FaultKind::DupDone) => {
                        let body = reply.encode();
                        send_reply(writer, proto::K_INSTANCE_DONE, &body)?;
                        send_reply(writer, proto::K_INSTANCE_DONE, &body)?;
                    }
                    _ => send_reply(writer, proto::K_INSTANCE_DONE, &reply.encode())?,
                }
            }
            Some((proto::K_HEARTBEAT, _)) => {
                // Coordinators don't beat at workers today; tolerate
                // it anyway (a future bidirectional lease costs us
                // nothing here).
            }
            Some((kind, _)) => {
                return Err(WilkinsError::Comm(format!(
                    "worker {worker_id}: unexpected control frame kind {kind}"
                )));
            }
        }
    }
    if let Some(mesh) = held.take() {
        mesh.shutdown();
    }
    Ok(())
}

/// Never returns: the thread (or process) plays dead without closing
/// its sockets.
fn park_forever() -> ! {
    loop {
        std::thread::park();
    }
}

/// Send one control reply and push it to the kernel immediately — the
/// coordinator is blocked on it, so a staged reply must not wait for
/// the I/O thread's loop boundary. (A DupDone's two replies stage
/// back-to-back and leave in the one flush.)
fn send_reply(writer: &Arc<FrameWriter>, kind: u8, body: &[u8]) -> Result<()> {
    writer.send(kind, body)?;
    writer.flush_blocking()
}

/// Attach the AOT engine when the run names an artifacts dir that
/// actually holds a manifest (same sniff as the CLI's run path).
fn with_engine_if_present(w: Wilkins, artifacts: &str) -> Result<Wilkins> {
    if artifacts.is_empty() {
        return Ok(w);
    }
    let dir = PathBuf::from(artifacts);
    if !dir.join("manifest.tsv").exists() {
        return Ok(w);
    }
    let handle = crate::runtime::shared_engine(&dir)?;
    Ok(w.with_engine(handle))
}

/// Threads currently alive in this process, from
/// `/proc/self/status` (`None` off Linux or on any parse surprise).
fn proc_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("Threads:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn serve_world(
    io: &IoRt,
    my_id: usize,
    peer_listener: &TcpListener,
    msg: &LaunchWorld,
    clock: Clock,
) -> Result<(WorldDone, rendezvous::MeshWorld)> {
    let mut w = Wilkins::from_yaml_str(&msg.config_src, builtin_registry())?
        .with_workdir(PathBuf::from(&msg.workdir))
        .with_time_scale(msg.time_scale);
    w = with_engine_if_present(w, &msg.artifacts)?;

    // The mesh shares the worker's one I/O thread: N peers, one
    // poller, O(1) threads however wide the pool fans out.
    let mesh = rendezvous::build_mesh_world_on(io, my_id, peer_listener, msg)?;
    let hosted: Vec<usize> = msg
        .owner_of
        .iter()
        .enumerate()
        .filter(|(_, &owner)| owner as usize == my_id)
        .map(|(r, _)| r)
        .collect();
    let recorder = w.recorder();
    let outcomes = w.run_hosted(&mesh.world, &hosted)?;
    // Scalability smoke hook: report this process's thread count now
    // that the world ran and its rank threads have joined — the
    // steady-state figure CI asserts is O(1) in pool width.
    if std::env::var("WILKINS_DEBUG_THREADS").as_deref() == Ok("1") {
        if let Some(n) = proc_thread_count() {
            eprintln!("wilkins-threads: worker={my_id} threads={n}");
        }
    }
    // The recorder's spans are relative to the recorder's own origin
    // (created with the Wilkins above); rebase them onto the worker
    // clock so they share a timeline with the telemetry samples the
    // coordinator aligned clocks from.
    let base = clock.since_origin(recorder.origin_instant());
    let spans = recorder
        .spans()
        .into_iter()
        .map(|mut s| {
            s.start += base;
            s.end += base;
            s
        })
        .collect();
    let done = WorldDone {
        bytes_sent: mesh.world.bytes_sent(),
        msgs_sent: mesh.world.msgs_sent(),
        outcomes: outcomes
            .into_iter()
            .map(|o| RankOutcomeWire {
                node: o.node as u64,
                stats: o.stats,
                error: o.error.unwrap_or_default(),
            })
            .collect(),
        error: String::new(),
        spans,
        t_mono_s: clock.now_s(),
    };
    Ok((done, mesh))
}

fn serve_instance(msg: &RunInstance) -> Result<InstanceDone> {
    let spec = EnsembleSpec::from_yaml_str(&msg.spec_src, Path::new(&msg.base_dir))?;
    let idx = msg.instance_idx as usize;
    let inst = spec.instances.get(idx).ok_or_else(|| {
        WilkinsError::Config(format!(
            "RunInstance names instance #{idx} but the spec has {}",
            spec.instances.len()
        ))
    })?;
    let mut w = Wilkins::new(inst.cfg.clone(), builtin_registry())?
        .with_workdir(PathBuf::from(&msg.workdir))
        .with_time_scale(msg.time_scale);
    w = with_engine_if_present(w, &msg.artifacts)?;
    let recorder = w.recorder();
    match w.run() {
        Ok(report) => Ok(InstanceDone {
            error: String::new(),
            report: Some(report),
            spans: recorder.spans(),
            idem_key: msg.idem_key,
        }),
        Err(e) => Ok(InstanceDone {
            error: e.to_string(),
            report: None,
            spans: recorder.spans(),
            idem_key: msg.idem_key,
        }),
    }
}
