//! Unit tests for the multi-process substrate: frame codec, protocol
//! roundtrips, and a real socket-backed world — two mesh sides with
//! independent `Mailboxes`/`World`s (exactly what two worker processes
//! hold), joined over loopback TCP inside one test process so p2p,
//! collectives and intercommunicators can be asserted end to end.

use std::io::Cursor;
use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use crate::comm::{InterComm, Payload, World};

use super::codec::{self, FrameDecoder, NbFrameReader, NbRead, HEADER_LEN, MAX_FRAME};
use super::proto::{
    self, Hello, InstanceDone, LaunchWorld, RankOutcomeWire, RunInstance, WorldDone,
};
use super::rendezvous::{build_mesh_world, MeshWorld};

#[test]
fn frame_roundtrip_blocking() {
    let mut buf: Vec<u8> = Vec::new();
    codec::write_frame(&mut buf, 7, b"hello").unwrap();
    codec::write_frame(&mut buf, 9, &[]).unwrap();
    let mut cur = Cursor::new(buf);
    assert_eq!(codec::read_frame(&mut cur).unwrap(), Some((7, b"hello".to_vec())));
    assert_eq!(codec::read_frame(&mut cur).unwrap(), Some((9, Vec::new())));
    assert_eq!(codec::read_frame(&mut cur).unwrap(), None, "clean EOF at boundary");
}

#[test]
fn eof_inside_frame_is_error() {
    let mut buf: Vec<u8> = Vec::new();
    codec::write_frame(&mut buf, 1, b"truncated body").unwrap();
    buf.truncate(HEADER_LEN + 3);
    let mut cur = Cursor::new(buf);
    assert!(codec::read_frame(&mut cur).is_err());

    // EOF inside the header is also an error (only boundary EOF is
    // a clean close).
    let mut cur = Cursor::new(vec![1u8, 2]);
    assert!(codec::read_frame(&mut cur).is_err());
}

#[test]
fn oversize_header_is_rejected() {
    let mut buf = ((MAX_FRAME as u32) + 1).to_le_bytes().to_vec();
    buf.push(0); // kind
    let mut cur = Cursor::new(buf.clone());
    assert!(codec::read_frame(&mut cur).is_err());
    let mut dec = FrameDecoder::new();
    dec.feed(&buf);
    assert!(dec.next_frame().is_err());
}

#[test]
fn decoder_handles_split_feeds() {
    let mut stream: Vec<u8> = Vec::new();
    codec::write_frame(&mut stream, 3, b"abc").unwrap();
    codec::write_frame(&mut stream, 4, b"defgh").unwrap();
    // Feed one byte at a time: frames must come out whole, in order.
    let mut dec = FrameDecoder::new();
    let mut out = Vec::new();
    for b in &stream {
        dec.feed(std::slice::from_ref(b));
        while let Some(f) = dec.next_frame().unwrap() {
            out.push(f);
        }
    }
    assert_eq!(out, vec![(3, b"abc".to_vec()), (4, b"defgh".to_vec())]);
    assert_eq!(dec.pending(), 0);
}

#[test]
fn hello_roundtrip_and_magic_check() {
    let h = Hello { worker_id: 3, peer_addr: "127.0.0.1:4042".into() };
    assert_eq!(Hello::decode(&h.encode()).unwrap(), h);
    let mut bad = h.encode();
    bad[0] ^= 0xFF;
    assert!(Hello::decode(&bad).is_err());
}

#[test]
fn control_messages_roundtrip() {
    let lw = LaunchWorld {
        config_src: "tasks: []\n".into(),
        workdir: "/tmp/w".into(),
        artifacts: String::new(),
        time_scale: 0.25,
        total_ranks: 12,
        endpoints: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
        owner_of: vec![0, 0, 0, 0, 1, 1, 1, 1, 1, 0, 0, 0],
        heartbeat_ms: 250,
        heartbeat_deadline_ms: 5000,
    };
    assert_eq!(LaunchWorld::decode(&lw.encode()).unwrap(), lw);

    let wd = WorldDone {
        bytes_sent: 1024,
        msgs_sent: 7,
        outcomes: vec![RankOutcomeWire {
            node: 2,
            stats: crate::lowfive::VolStats {
                files_served: 3,
                bytes_served: 999,
                bytes_shared: 640,
                bytes_copied: 359,
                serve_wait: Duration::from_millis(12),
                ..Default::default()
            },
            error: String::new(),
        }],
        error: String::new(),
        spans: vec![crate::metrics::Span {
            rank: 3,
            kind: crate::metrics::SpanKind::Transfer,
            label: "serve particles".into(),
            start: 1.25,
            end: 1.5,
            attrs: vec![("file".into(), "particles".into())],
        }],
        t_mono_s: 42.5,
    };
    let back = WorldDone::decode(&wd.encode()).unwrap();
    assert_eq!(back.bytes_sent, 1024);
    assert_eq!(back.outcomes.len(), 1);
    assert_eq!(back.outcomes[0].node, 2);
    assert_eq!(back.outcomes[0].stats.bytes_served, 999);
    assert_eq!(back.outcomes[0].stats.bytes_shared, 640);
    assert_eq!(back.outcomes[0].stats.bytes_copied, 359);
    assert!((back.outcomes[0].stats.serve_wait.as_secs_f64() - 0.012).abs() < 1e-9);
    assert_eq!(back.spans.len(), 1);
    assert_eq!(back.spans[0].label, "serve particles");
    assert_eq!(back.spans[0].attrs, vec![("file".to_string(), "particles".to_string())]);
    assert!((back.t_mono_s - 42.5).abs() < 1e-9);

    let ri = RunInstance {
        spec_src: "ensemble: {}\n".into(),
        base_dir: ".".into(),
        instance_idx: 4,
        workdir: "/tmp/x/pipe[4]".into(),
        artifacts: "artifacts".into(),
        time_scale: 1.0,
        idem_key: 41,
    };
    assert_eq!(RunInstance::decode(&ri.encode()).unwrap(), ri);

    let id = InstanceDone {
        error: String::new(),
        report: Some(crate::coordinator::RunReport {
            elapsed: Duration::from_millis(250),
            total_ranks: 4,
            bytes_sent: 10,
            msgs_sent: 2,
            nodes: vec![],
            faults: crate::coordinator::FaultStats {
                lost_workers: 1,
                retries: 2,
                heartbeat_misses: 3,
                dup_done: 4,
            },
            telemetry: Default::default(),
        }),
        spans: vec![crate::metrics::Span {
            rank: 1,
            kind: crate::metrics::SpanKind::Transfer,
            label: "serve".into(),
            start: 0.5,
            end: 0.75,
            attrs: vec![],
        }],
        idem_key: 41,
    };
    let back = InstanceDone::decode(&id.encode()).unwrap();
    assert!(back.error.is_empty());
    assert_eq!(back.report.as_ref().unwrap().total_ranks, 4);
    let f = back.report.as_ref().unwrap().faults;
    assert_eq!((f.lost_workers, f.retries, f.heartbeat_misses, f.dup_done), (1, 2, 3, 4));
    assert_eq!(back.idem_key, 41);
    assert_eq!(back.spans.len(), 1);
    assert_eq!(back.spans[0].kind, crate::metrics::SpanKind::Transfer);

    assert_eq!(proto::decode_peer_hello(&proto::encode_peer_hello(5)).unwrap(), 5);
}

#[test]
fn data_envelope_roundtrip() {
    let body = proto::encode_data(3, 1, 42, 7, b"payload bytes");
    let msg = proto::decode_data(&body).unwrap();
    assert_eq!(
        (msg.dst_global, msg.src_global, msg.comm_id, msg.tag, msg.payload.as_slice()),
        (3, 1, 42, 7, b"payload bytes".as_slice())
    );
}

#[test]
fn chunked_envelope_roundtrip() {
    let payload: Vec<u8> = (0..1000u32).flat_map(u32::to_le_bytes).collect();
    let chunks = proto::chunk_payload(3, 1, 42, 7, 99, &Payload::from(payload.clone()), 128);
    assert_eq!(chunks.len(), (payload.len() + 127) / 128);
    let mut asm = proto::ChunkAssembler::new();
    let mut out = None;
    for c in chunks {
        let c = proto::decode_data_chunk(&proto::encode_data_chunk(&c)).unwrap();
        if let Some(msg) = asm.feed(c).unwrap() {
            assert!(out.is_none(), "only the final chunk completes");
            out = Some(msg);
        }
    }
    let msg = out.expect("reassembled");
    assert_eq!((msg.dst_global, msg.src_global, msg.comm_id, msg.tag), (3, 1, 42, 7));
    assert_eq!(msg.payload, payload);
    assert_eq!(asm.in_flight(), 0);
}

#[test]
fn chunk_assembler_rejects_desync() {
    let payload = Payload::from(vec![7u8; 64]);
    let chunks = proto::chunk_payload(0, 1, 2, 3, 5, &payload, 16);
    let mut asm = proto::ChunkAssembler::new();
    asm.feed(chunks[0].clone()).unwrap();
    // Skipping a chunk (offset gap) must fail loudly, not corrupt.
    assert!(asm.feed(chunks[2].clone()).is_err());
}

#[test]
fn chunk_assembler_rejects_absurd_total_len() {
    // A corrupt declared length must fail the link cleanly, never
    // drive the allocation.
    let mut c = proto::chunk_payload(0, 1, 2, 3, 5, &Payload::from(vec![1, 2, 3]), 16).remove(0);
    c.total_len = u64::MAX;
    let mut asm = proto::ChunkAssembler::new();
    assert!(asm.feed(c).is_err());
    assert_eq!(asm.in_flight(), 0);
}

/// Satellite property: chunked data envelopes survive the full
/// receive path — frames split at arbitrary byte boundaries by the
/// incremental decoder, chunk streams from different senders
/// interleaved on one link — and reassemble byte-identically.
#[test]
fn prop_chunked_frames_reassemble_under_split_reads() {
    crate::proptest_lite::run_prop("chunk-reassembly-split-reads", 60, |rng| {
        // Two concurrent senders on one link, each with one message.
        let mk = |src: u64, rng: &mut crate::proptest_lite::Rng| -> Vec<u8> {
            let n = rng.usize(0, 5000);
            (0..n).map(|i| (i as u64 * 31 + src) as u8).collect()
        };
        let pay_a = mk(1, rng);
        let pay_b = mk(2, rng);
        let chunk_size = rng.usize(1, 257);
        let chunks_a =
            proto::chunk_payload(9, 1, 4, 8, 100, &Payload::from(pay_a.clone()), chunk_size);
        let chunks_b =
            proto::chunk_payload(9, 2, 4, 8, 101, &Payload::from(pay_b.clone()), chunk_size);

        // Interleave the two chunk streams randomly (preserving each
        // stream's own order, as the per-peer write lock does), then
        // frame them onto one byte stream.
        let mut stream: Vec<u8> = Vec::new();
        let (mut ia, mut ib) = (0usize, 0usize);
        while ia < chunks_a.len() || ib < chunks_b.len() {
            let take_a = ib >= chunks_b.len() || (ia < chunks_a.len() && rng.bool());
            let c = if take_a {
                ia += 1;
                &chunks_a[ia - 1]
            } else {
                ib += 1;
                &chunks_b[ib - 1]
            };
            codec::write_frame(&mut stream, proto::K_DATA_CHUNK, &proto::encode_data_chunk(c))
                .unwrap();
        }

        // Feed the stream through the incremental decoder at random
        // split points, reassembling as the pump would.
        let mut dec = FrameDecoder::new();
        let mut asm = proto::ChunkAssembler::new();
        let mut done: Vec<proto::DataMsg> = Vec::new();
        let mut pos = 0usize;
        while pos < stream.len() {
            let step = rng.usize(1, 64.min(stream.len() - pos) + 1);
            dec.feed(&stream[pos..pos + step]);
            pos += step;
            while let Some((kind, body)) = dec.next_frame().unwrap() {
                assert_eq!(kind, proto::K_DATA_CHUNK);
                if let Some(msg) = asm.feed(proto::decode_data_chunk(&body).unwrap()).unwrap() {
                    done.push(msg);
                }
            }
        }
        assert_eq!(done.len(), 2, "both messages complete");
        assert_eq!(asm.in_flight(), 0);
        for msg in done {
            let want = if msg.src_global == 1 { &pay_a } else { &pay_b };
            assert_eq!(msg.payload, *want, "payload torn for src {}", msg.src_global);
            assert_eq!((msg.dst_global, msg.comm_id, msg.tag), (9, 4, 8));
        }
    });
}

/// Satellite property: the pooled plane (payload slices + vectored
/// headers) is bit-identical on the wire to the historical owned-Vec
/// path, and reassembles identically when the frames are read at
/// arbitrary split points straddling chunk boundaries.
#[test]
fn prop_payload_slicing_matches_owned_chunk_path() {
    crate::proptest_lite::run_prop("payload-vs-owned-chunks", 60, |rng| {
        let n = rng.usize(0, 4000);
        let bytes: Vec<u8> = (0..n).map(|i| (i as u64 * 37 + 11) as u8).collect();
        let payload = Payload::from(bytes.clone());
        let chunk_size = rng.usize(1, 513);

        let sliced = proto::chunk_payload(3, 1, 9, 7, 42, &payload, chunk_size);
        let owned = proto::chunk_payload_owned(3, 1, 9, 7, 42, &bytes, chunk_size);
        assert_eq!(sliced.len(), owned.len());

        // Frame every chunk both ways: the legacy concatenating body
        // and the vectored header + raw bytes must be byte-identical
        // on the wire.
        let mut stream: Vec<u8> = Vec::new();
        for (s, o) in sliced.iter().zip(&owned) {
            assert_eq!(s, o, "slice and copy chunks must agree field-for-field");
            let legacy_body = proto::encode_data_chunk(o);
            let head = proto::encode_data_chunk_header(s);
            let mut vectored_body = head.as_slice().to_vec();
            vectored_body.extend_from_slice(&s.bytes);
            assert_eq!(
                legacy_body, vectored_body,
                "vectored header + slice must equal the concatenated encode"
            );
            codec::write_frame(&mut stream, proto::K_DATA_CHUNK, &legacy_body).unwrap();
        }

        // Split reads straddling chunk boundaries: both decode paths
        // (copy-out and payload-slicing) must reassemble the original
        // bytes exactly.
        let mut dec = FrameDecoder::new();
        let mut asm_sliced = proto::ChunkAssembler::new();
        let mut asm_owned = proto::ChunkAssembler::new();
        let (mut got_sliced, mut got_owned) = (None, None);
        let mut pos = 0usize;
        while pos < stream.len() {
            let step = rng.usize(1, 97.min(stream.len() - pos) + 1);
            dec.feed(&stream[pos..pos + step]);
            pos += step;
            while let Some((kind, body)) = dec.next_frame().unwrap() {
                assert_eq!(kind, proto::K_DATA_CHUNK);
                let body = Payload::from(body);
                if let Some(m) =
                    asm_sliced.feed(proto::decode_data_chunk_payload(&body).unwrap()).unwrap()
                {
                    got_sliced = Some(m.payload);
                }
                if let Some(m) =
                    asm_owned.feed(proto::decode_data_chunk(&body).unwrap()).unwrap()
                {
                    got_owned = Some(m.payload);
                }
            }
        }
        let got_sliced = got_sliced.expect("sliced path completes");
        let got_owned = got_owned.expect("owned path completes");
        assert_eq!(got_sliced, bytes, "sliced path must reproduce the payload");
        assert_eq!(got_owned, bytes, "owned path must reproduce the payload");
        assert_eq!(asm_sliced.in_flight(), 0);
        assert_eq!(asm_owned.in_flight(), 0);
    });
}

#[test]
fn vectored_and_concat_frames_are_wire_identical() {
    let head = b"header-bytes".to_vec();
    let tail = vec![5u8; 3000];
    let mut whole = head.clone();
    whole.extend_from_slice(&tail);

    let mut concat: Vec<u8> = Vec::new();
    codec::write_frame(&mut concat, 8, &whole).unwrap();
    let mut vectored: Vec<u8> = Vec::new();
    codec::write_frame_vectored(&mut vectored, 8, &[&head, &tail]).unwrap();
    assert_eq!(concat, vectored);

    // And the pooled blocking reader agrees with the owned one.
    let mut cur = Cursor::new(concat.clone());
    let (kind, body) = codec::read_frame(&mut cur).unwrap().unwrap();
    let mut cur = Cursor::new(vectored);
    let (pkind, pbody) = codec::read_frame_payload(&mut cur).unwrap().unwrap();
    assert_eq!((kind, body.as_slice()), (pkind, pbody.as_slice()));
}

#[test]
fn decoder_reclaims_staging_capacity_after_drain() {
    let big = vec![3u8; 2 << 20];
    let mut stream: Vec<u8> = Vec::new();
    codec::write_frame(&mut stream, 1, &big).unwrap();
    let mut dec = FrameDecoder::new();
    dec.feed(&stream);
    let (_, body) = dec.next_frame().unwrap().unwrap();
    assert_eq!(body.len(), big.len());
    assert_eq!(dec.pending(), 0);
    assert!(
        dec.capacity() <= 64 * 1024,
        "drained decoder must not hold peak-size capacity (got {})",
        dec.capacity()
    );
}

/// Two mesh sides — two independent worlds, as two worker processes
/// would hold — joined over loopback. Ranks 0..2 live on side 0,
/// ranks 2..4 on side 1.
fn mesh_pair() -> (MeshWorld, MeshWorld) {
    let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
    let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let endpoints = vec![
        l0.local_addr().unwrap().to_string(),
        l1.local_addr().unwrap().to_string(),
    ];
    let msg = LaunchWorld {
        config_src: String::new(),
        workdir: String::new(),
        artifacts: String::new(),
        time_scale: 1.0,
        total_ranks: 4,
        endpoints,
        owner_of: vec![0, 0, 1, 1],
        // Liveness off: these tests hold mesh worlds across long
        // assertion sequences with no beat threads running.
        heartbeat_ms: 0,
        heartbeat_deadline_ms: 0,
    };
    let m0 = msg.clone();
    let h = thread::spawn(move || build_mesh_world(0, &l0, &m0).unwrap());
    let side1 = build_mesh_world(1, &l1, &msg).unwrap();
    let side0 = h.join().unwrap();
    (side0, side1)
}

#[test]
fn socket_world_p2p_across_the_mesh() {
    let (side0, side1) = mesh_pair();
    let w0 = side0.world.clone();
    let w1 = side1.world.clone();
    let t = thread::spawn(move || {
        let c = w0.comm_world(0);
        c.send(2, 5, b"over the wire");
        let (src, m) = c.recv(2, 6).unwrap();
        assert_eq!((src, m.as_slice()), (2, b"back".as_slice()));
    });
    let c = w1.comm_world(2);
    let (src, m) = c.recv(0, 5).unwrap();
    assert_eq!((src, m.as_slice()), (0, b"over the wire".as_slice()));
    c.send(0, 6, b"back");
    t.join().unwrap();
    // Each side counted exactly its own sends.
    assert_eq!(side0.world.msgs_sent(), 1);
    assert_eq!(side1.world.msgs_sent(), 1);
    side0.shutdown();
    side1.shutdown();
}

/// Serializes tests that flip the process-global shm knobs
/// (`set_enabled`, `set_min`, `set_dir_override`) — and the chunk test
/// below, whose inline-path pin must not race a flip. Poisoning is
/// recovered: a failed sibling should not cascade.
static SHM_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn shm_lock() -> std::sync::MutexGuard<'static, ()> {
    SHM_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// One synchronous rank 0 → rank 2 delivery across a mesh pair,
/// returning the received bytes.
fn exchange(w0: &World, w1: &World, tag: u64, data: Vec<u8>) -> Vec<u8> {
    let h = {
        let w1 = w1.clone();
        thread::spawn(move || {
            let c = w1.comm_world(2);
            let (src, m) = c.recv(0, tag).unwrap();
            assert_eq!(src, 0);
            m.as_slice().to_vec()
        })
    };
    w0.comm_world(0).send_owned(2, tag, data);
    h.join().unwrap()
}

#[test]
fn socket_world_chunks_large_payloads() {
    // A payload above CHUNK_SIZE must cross the mesh in bounded
    // pieces and arrive byte-identical through the ordinary recv path.
    // Pinned to the inline plane: with shm at its default-on, a
    // payload this size would route around chunking entirely.
    let _guard = shm_lock();
    super::shm::set_enabled(false);
    let (side0, side1) = mesh_pair();
    let w0 = side0.world.clone();
    let w1 = side1.world.clone();
    let big: Vec<u8> = (0..(codec::CHUNK_SIZE + codec::CHUNK_SIZE / 2))
        .map(|i| (i * 131) as u8)
        .collect();
    let want = big.clone();
    let t = thread::spawn(move || {
        let c = w0.comm_world(0);
        c.send_owned(2, 5, big);
    });
    let c = w1.comm_world(2);
    let (src, m) = c.recv(0, 5).unwrap();
    assert_eq!(src, 0);
    assert_eq!(m.len(), want.len());
    assert!(m == want, "chunked payload must reassemble byte-identically");
    t.join().unwrap();
    side0.shutdown();
    side1.shutdown();
    super::shm::set_enabled(true);
}

/// The shm plane and the inline socket path must deliver bit-identical
/// payloads at every size — especially the boundary sizes where the
/// routing flips (the shm threshold, the chunk split) and the
/// degenerate zero-length body.
#[test]
fn shm_and_inline_deliveries_bit_identical_across_boundaries() {
    let _guard = shm_lock();
    let min0 = super::shm::shm_min();
    // A test-sized threshold keeps the straddle set cheap while still
    // exercising the same routing decision production takes at 64 KiB.
    super::shm::set_min(16 * 1024);
    let min = super::shm::shm_min();
    let chunk = codec::chunk_size();
    let (side0, side1) = mesh_pair();
    let check = |size: usize| {
        let data: Vec<u8> =
            (0..size).map(|i| (i.wrapping_mul(131) ^ (i >> 8)) as u8).collect();
        for shm_on in [false, true] {
            super::shm::set_enabled(shm_on);
            let got = exchange(&side0.world, &side1.world, 77, data.clone());
            assert!(
                got == data,
                "size {size} shm_on={shm_on}: delivery must be bit-identical"
            );
        }
    };
    for &size in &[0usize, 1, min - 1, min, min + 1, chunk - 1, chunk, chunk + 1] {
        check(size);
    }
    crate::proptest_lite::run_prop("shm-vs-inline-random-sizes", 6, |rng| {
        check(rng.usize(0, chunk + 64 * 1024));
    });
    super::shm::set_min(min0);
    super::shm::set_enabled(true);
    side0.shutdown();
    side1.shutdown();
}

/// Fallback: when a segment cannot be created (here: an unwritable
/// shm dir) a large payload must degrade to the inline path — same
/// bytes delivered, `shm_fallbacks` bumped, nothing else different.
#[cfg(unix)]
#[test]
fn shm_creation_failure_falls_back_inline() {
    let _guard = shm_lock();
    super::shm::set_enabled(true);
    super::shm::set_dir_override(Some("/proc/wilkins-shm-unwritable/nope".into()));
    let fb0 = crate::obs::Ctr::ShmFallbacks.get();
    let (side0, side1) = mesh_pair();
    let data: Vec<u8> = (0..256 * 1024).map(|i| (i * 67) as u8).collect();
    let got = exchange(&side0.world, &side1.world, 9, data.clone());
    super::shm::set_dir_override(None);
    assert!(got == data, "fallback delivery must be bit-identical");
    assert!(
        crate::obs::Ctr::ShmFallbacks.get() > fb0,
        "a failed segment creation must be counted as a fallback"
    );
    side0.shutdown();
    side1.shutdown();
}

#[test]
fn socket_world_collectives_and_intercomm() {
    let (side0, side1) = mesh_pair();
    let mut handles = Vec::new();
    for rank in 0..4usize {
        let world = if rank < 2 { side0.world.clone() } else { side1.world.clone() };
        handles.push(thread::spawn(move || {
            let c = world.comm_world(rank);
            // Collectives cross the mesh unmodified.
            c.barrier().unwrap();
            assert_eq!(c.allreduce_sum_u64(rank as u64).unwrap(), 6);
            let parts = c.allgather(&[rank as u8]).unwrap();
            assert_eq!(parts, vec![vec![0u8], vec![1], vec![2], vec![3]]);

            // Intercomm between the two process-local groups: ranks
            // {0,1} produce, {2,3} consume (1:1 pairing).
            let (group, peer): (&[usize], usize) = if rank < 2 {
                (&[0, 1], rank + 2)
            } else {
                (&[2, 3], rank - 2)
            };
            let local = world.comm_from_ranks(90, group, rank % 2);
            let remote: Vec<usize> = if rank < 2 { vec![2, 3] } else { vec![0, 1] };
            let ic = InterComm::new(local, 91, remote);
            if rank < 2 {
                ic.send(rank % 2, 3, &[rank as u8; 4]);
            } else {
                let (src, m) = ic.recv(rank % 2, 3).unwrap();
                assert_eq!(src, rank % 2);
                assert_eq!(m, vec![peer as u8; 4]);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    side0.shutdown();
    side1.shutdown();
}

/// Satellite 2: mesh teardown joins the I/O thread instead of
/// detaching it — after `shutdown()` both processes' I/O threads have
/// provably exited (no thread leak).
#[test]
fn mesh_shutdown_joins_io_threads() {
    let (side0, side1) = mesh_pair();
    let probe0 = side0.io_finished_probe();
    let probe1 = side1.io_finished_probe();
    assert!(!probe0.load(std::sync::atomic::Ordering::SeqCst));
    assert!(!probe1.load(std::sync::atomic::Ordering::SeqCst));
    side0.shutdown();
    side1.shutdown();
    // shutdown() drops the last IoRt handle, whose guard joins the
    // thread before returning — so the flags are set by now, no race.
    assert!(
        probe0.load(std::sync::atomic::Ordering::SeqCst),
        "side 0's io thread must be joined by shutdown"
    );
    assert!(
        probe1.load(std::sync::atomic::Ordering::SeqCst),
        "side 1's io thread must be joined by shutdown"
    );
}

/// A reader that returns `WouldBlock` before every slice of the
/// stream it serves — the worst-case readiness interleaving a
/// nonblocking socket can produce.
struct ChoppyReader {
    data: Vec<u8>,
    pos: usize,
    step: usize,
    /// Alternator: every other call yields `WouldBlock`.
    ready: bool,
}

impl ChoppyReader {
    fn new(data: Vec<u8>, step: usize) -> ChoppyReader {
        ChoppyReader { data, pos: 0, step, ready: false }
    }
}

impl std::io::Read for ChoppyReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if !self.ready {
            self.ready = true;
            return Err(std::io::ErrorKind::WouldBlock.into());
        }
        self.ready = false;
        let n = self.step.min(buf.len()).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Drive an [`NbFrameReader`] over a choppy stream to exhaustion,
/// counting the `WouldBlock` suspensions it absorbed.
fn drain_choppy(reader: &mut ChoppyReader) -> (Vec<(u8, Payload)>, usize) {
    let mut nb = NbFrameReader::new();
    let mut frames = Vec::new();
    let mut suspensions = 0usize;
    loop {
        match nb.read_from(reader).unwrap() {
            NbRead::Frame(f) => frames.push(f),
            NbRead::WouldBlock => {
                if reader.pos == reader.data.len() {
                    // Stream exhausted mid-"wait": treat as done (a
                    // real socket would eventually EOF; Cursor-style
                    // test data just runs dry).
                    break;
                }
                suspensions += 1;
            }
            NbRead::Eof => break,
        }
    }
    (frames, suspensions)
}

/// Satellite 3: a chunked 16 MiB payload crosses the nonblocking
/// reader with a `WouldBlock` before every 4093-byte split — every
/// header and body boundary gets torn — and reassembles
/// byte-identically.
#[test]
fn nb_reader_reassembles_chunked_16mib_through_wouldblock_storm() {
    let payload: Vec<u8> = (0..16 * 1024 * 1024usize).map(|i| (i * 131 + 7) as u8).collect();
    let chunks = proto::chunk_payload(
        2,
        1,
        4,
        8,
        77,
        &Payload::from(payload.clone()),
        codec::CHUNK_SIZE,
    );
    let mut stream: Vec<u8> = Vec::new();
    for c in &chunks {
        codec::write_frame(&mut stream, proto::K_DATA_CHUNK, &proto::encode_data_chunk(c))
            .unwrap();
    }

    // 4093 is prime, so the read boundaries drift through every
    // offset of the repeating frame structure.
    let mut reader = ChoppyReader::new(stream, 4093);
    let (frames, suspensions) = drain_choppy(&mut reader);
    assert_eq!(frames.len(), chunks.len(), "every chunk frame must surface");
    assert!(
        suspensions >= frames.len(),
        "the storm must actually have interrupted reads \
         ({suspensions} suspensions over {} frames)",
        frames.len()
    );

    let mut asm = proto::ChunkAssembler::new();
    let mut out = None;
    for (kind, body) in frames {
        assert_eq!(kind, proto::K_DATA_CHUNK);
        if let Some(msg) = asm.feed(proto::decode_data_chunk(&body).unwrap()).unwrap() {
            assert!(out.is_none(), "only the final chunk completes");
            out = Some(msg);
        }
    }
    let msg = out.expect("reassembled");
    assert_eq!((msg.dst_global, msg.src_global, msg.comm_id, msg.tag), (2, 1, 4, 8));
    assert!(msg.payload == payload, "payload must survive byte-identically");
    assert_eq!(asm.in_flight(), 0);
}

/// Satellite 3, small-frame edge: one byte per read, `WouldBlock`
/// between every single byte — including a zero-length body, which
/// must complete without misreading `read(&mut []) == 0` as EOF.
#[test]
fn nb_reader_survives_per_byte_wouldblock() {
    let mut stream: Vec<u8> = Vec::new();
    codec::write_frame(&mut stream, 7, b"tiny").unwrap();
    codec::write_frame(&mut stream, 9, &[]).unwrap();
    codec::write_frame(&mut stream, 8, b"x").unwrap();

    let mut reader = ChoppyReader::new(stream, 1);
    let (frames, suspensions) = drain_choppy(&mut reader);
    let got: Vec<(u8, Vec<u8>)> =
        frames.into_iter().map(|(k, b)| (k, b.as_slice().to_vec())).collect();
    assert_eq!(
        got,
        vec![(7, b"tiny".to_vec()), (9, Vec::new()), (8, b"x".to_vec())],
        "frames must come out whole and in order"
    );
    assert!(suspensions > 10, "per-byte feeding must suspend constantly");
}

/// The nonblocking reader keeps the blocking readers' desync rules:
/// EOF inside a header or body is an error, only boundary EOF is
/// clean.
#[test]
fn nb_reader_eof_rules_match_blocking_reader() {
    // Clean boundary EOF.
    let mut whole: Vec<u8> = Vec::new();
    codec::write_frame(&mut whole, 3, b"abc").unwrap();
    let mut nb = NbFrameReader::new();
    let mut cur = Cursor::new(whole.clone());
    assert!(matches!(nb.read_from(&mut cur).unwrap(), NbRead::Frame((3, _))));
    assert!(matches!(nb.read_from(&mut cur).unwrap(), NbRead::Eof));

    // EOF mid-header errors.
    let mut nb = NbFrameReader::new();
    let mut cur = Cursor::new(whole[..3].to_vec());
    assert!(nb.read_from(&mut cur).is_err());

    // EOF mid-body errors.
    let mut nb = NbFrameReader::new();
    let mut cur = Cursor::new(whole[..HEADER_LEN + 1].to_vec());
    assert!(nb.read_from(&mut cur).is_err());

    // Oversize header rejected before any allocation.
    let mut bad = ((MAX_FRAME as u32) + 1).to_le_bytes().to_vec();
    bad.push(0);
    let mut nb = NbFrameReader::new();
    let mut cur = Cursor::new(bad);
    assert!(nb.read_from(&mut cur).is_err());
}
