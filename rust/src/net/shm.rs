//! Shared-memory payload plane for co-located workers.
//!
//! Every `wilkins up` worker pair on one host pays two kernel copies
//! per payload byte through loopback sockets. For payloads at or above
//! [`shm_min`] (default 64 KiB, `WILKINS_SHM_MIN` tunable) the
//! transport instead writes the bytes once into a pooled shm segment
//! and sends only a small `K_DATA_SHM` descriptor frame over the
//! socket; the consumer maps the segment once per link and surfaces it
//! as a [`Payload`](crate::comm::buf::Payload) backed by the mapping,
//! so slicing and lowfive's
//! borrow-decoding work unchanged. Reclamation rides a `K_SHM_ACK`
//! frame staged from the last payload view's drop and flushed by the
//! existing `wk-io` thread — no new threads.
//!
//! Deviation from the fd-passing sketch: the mesh links are TCP
//! loopback sockets and stable `std` has no `SCM_RIGHTS` ancillary
//! plumbing, so segments are *named* tmpfs files (`/dev/shm` on Linux,
//! the system temp dir elsewhere) created with `memfd`-like semantics
//! — create, `set_len`, map shared, unlink on pool drop — and the
//! descriptor ships the file name instead of an fd. A stale-segment
//! sweep at pool creation reclaims files leaked by crashed processes.
//!
//! Everything degrades: if a segment cannot be created (pool
//! exhausted, unwritable dir, non-unix host) the payload falls back to
//! the inline socket path and `shm_fallbacks` is bumped — delivery
//! semantics are identical either way, which `net::tests` sweeps
//! property-style.

use std::fs::{File, OpenOptions};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::comm::buf::{self, ByteRegion};
use crate::error::Result;
use crate::obs::counters::Ctr;

/// Default minimum payload size that takes the shm plane.
pub const DEFAULT_SHM_MIN: usize = 64 * 1024;

/// Most segments one pool will hold live (mirrors `BufPool`'s parked
/// bounds): beyond this, large sends fall back to the inline path
/// until acks return.
const MAX_SEGMENTS: usize = 16;

/// Byte budget across one pool's segments.
const MAX_TOTAL_BYTES: usize = 1 << 28; // 256 MiB

/// Segment capacities round up to this grain so slightly-different
/// payload sizes recycle the same segment.
const CAP_GRAIN: usize = 64 * 1024;

#[cfg(unix)]
mod sys {
    //! Minimal mmap surface (the poller owns the poll/fcntl surface).
    #![allow(non_camel_case_types)]

    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 0x1;
    pub const PROT_WRITE: c_int = 0x2;
    pub const MAP_SHARED: c_int = 0x01;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;
}

// ---------------------------------------------------------------------------
// Process-wide knobs
// ---------------------------------------------------------------------------

fn enabled_flag() -> &'static AtomicBool {
    static ENABLED: OnceLock<AtomicBool> = OnceLock::new();
    ENABLED.get_or_init(|| {
        let on = std::env::var("WILKINS_SHM").map(|v| v != "0").unwrap_or(true);
        AtomicBool::new(cfg!(unix) && on)
    })
}

/// Is the shm plane on for this process? Defaults to on (unix hosts);
/// `WILKINS_SHM=0` disables it, reproducing the inline-only wire.
pub fn enabled() -> bool {
    enabled_flag().load(Ordering::Relaxed)
}

/// Test/bench hook: flip the shm plane at runtime (the env toggle is
/// read once). Guard concurrent uses — this is process-global state.
pub fn set_enabled(on: bool) {
    enabled_flag().store(cfg!(unix) && on, Ordering::Relaxed);
}

fn min_cell() -> &'static AtomicU64 {
    static MIN: OnceLock<AtomicU64> = OnceLock::new();
    MIN.get_or_init(|| {
        let v = match std::env::var("WILKINS_SHM_MIN") {
            Ok(s) => match s.trim().parse::<u64>() {
                Ok(n) if n > 0 => n,
                _ => {
                    eprintln!(
                        "wilkins: ignoring WILKINS_SHM_MIN={s:?} \
                         (want a positive byte count); using {DEFAULT_SHM_MIN}"
                    );
                    DEFAULT_SHM_MIN as u64
                }
            },
            Err(_) => DEFAULT_SHM_MIN as u64,
        };
        AtomicU64::new(v)
    })
}

/// Payload size (bytes) at or above which the transport prefers the
/// shm plane (`WILKINS_SHM_MIN`, default 64 KiB).
pub fn shm_min() -> usize {
    min_cell().load(Ordering::Relaxed) as usize
}

/// Test/bench hook: override the shm threshold at runtime.
pub fn set_min(bytes: usize) {
    min_cell().store(bytes.max(1) as u64, Ordering::Relaxed);
}

/// Directory override used by tests to force segment-creation failure
/// (point it at a non-writable path) without touching real tmpfs.
static DIR_OVERRIDE: Mutex<Option<PathBuf>> = Mutex::new(None);

pub(crate) fn set_dir_override(dir: Option<PathBuf>) {
    *DIR_OVERRIDE.lock().unwrap() = dir;
}

/// Where segment files live: `/dev/shm` when present (Linux tmpfs —
/// backing pages never touch disk), else the system temp dir.
pub(crate) fn shm_dir() -> PathBuf {
    if let Some(d) = DIR_OVERRIDE.lock().unwrap().clone() {
        return d;
    }
    let dev_shm = Path::new("/dev/shm");
    if dev_shm.is_dir() {
        dev_shm.to_path_buf()
    } else {
        std::env::temp_dir()
    }
}

/// Process-unique segment sequence (several mesh worlds can co-host in
/// one process; names must never collide).
static NEXT_SEG: AtomicU64 = AtomicU64::new(0);

fn segment_name(seg_id: u64) -> String {
    format!("wk-shm-{}-{}", std::process::id(), seg_id)
}

// ---------------------------------------------------------------------------
// Mapping
// ---------------------------------------------------------------------------

/// A shared, page-aligned mapping of one segment file. Producer maps
/// read-write, consumers read-only; the mapping unmaps on drop. The
/// ack protocol guarantees a producer only rewrites a segment after
/// every consumer view of the previous contents has dropped, so the
/// `&[u8]` handed out by [`ShmMap::as_slice`] never aliases a
/// concurrent write.
pub(crate) struct ShmMap {
    ptr: *mut u8,
    len: usize,
}

// Safety: the pointer is a MAP_SHARED mapping private to this struct;
// cross-thread access is read-only (consumer) or serialized by the
// pool's InFlight state machine (producer). See module docs.
unsafe impl Send for ShmMap {}
unsafe impl Sync for ShmMap {}

impl ShmMap {
    #[cfg(unix)]
    fn map(file: &File, len: usize, writable: bool) -> Result<ShmMap> {
        use std::os::unix::io::AsRawFd;
        let prot = if writable { sys::PROT_READ | sys::PROT_WRITE } else { sys::PROT_READ };
        let ptr = unsafe {
            sys::mmap(std::ptr::null_mut(), len, prot, sys::MAP_SHARED, file.as_raw_fd(), 0)
        };
        if ptr == sys::MAP_FAILED {
            return Err(crate::error::WilkinsError::Comm(format!(
                "mmap({len} bytes) failed: {}",
                std::io::Error::last_os_error()
            )));
        }
        Ok(ShmMap { ptr: ptr as *mut u8, len })
    }

    #[cfg(not(unix))]
    fn map(_file: &File, _len: usize, _writable: bool) -> Result<ShmMap> {
        Err(crate::error::WilkinsError::Comm("shm plane requires a unix host".into()))
    }

    pub(crate) fn as_slice(&self) -> &[u8] {
        // Safety: the mapping is valid for `len` bytes until Drop, and
        // the ack protocol serializes writes against reads.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for ShmMap {
    fn drop(&mut self) {
        #[cfg(unix)]
        unsafe {
            sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
        }
    }
}

/// Open and map an existing segment by name (consumer side). `cap` is
/// the capacity from the descriptor; the file must be at least that
/// large or the producer and consumer disagree about the segment.
pub(crate) fn open_map(name: &str, cap: usize) -> Result<Arc<ShmMap>> {
    let path = shm_dir().join(name);
    let file = File::open(&path).map_err(|e| {
        crate::error::WilkinsError::Comm(format!("shm segment {} missing: {e}", path.display()))
    })?;
    let meta_len = file.metadata().map(|m| m.len()).unwrap_or(0);
    if (meta_len as usize) < cap {
        return Err(crate::error::WilkinsError::Comm(format!(
            "shm segment {} truncated: file {meta_len} B < descriptor cap {cap} B",
            path.display()
        )));
    }
    Ok(Arc::new(ShmMap::map(&file, cap, false)?))
}

// ---------------------------------------------------------------------------
// Producer pool
// ---------------------------------------------------------------------------

struct Segment {
    id: u64,
    path: PathBuf,
    map: Arc<ShmMap>,
    cap: usize,
    /// False while a delivery is in flight (descriptor sent, ack not
    /// yet back): the segment must not be rewritten.
    free: bool,
}

struct PoolInner {
    segs: Vec<Segment>,
    total_bytes: usize,
}

/// Bounded pool of producer-side shm segments, one per mesh transport
/// (mirrors [`crate::comm::buf::BufPool`]'s role on the inline path).
/// Dropping the pool unlinks every segment file, so a clean shutdown
/// leaves no tmpfs litter; a sweep at creation reclaims files from
/// crashed processes. Lost acks (a consumer that died mid-delivery)
/// strand segments in flight — the pool then falls back to inline
/// sends rather than growing without bound.
pub struct ShmPool {
    inner: Mutex<PoolInner>,
}

impl ShmPool {
    /// A fresh pool; sweeps stale segment files once per process.
    pub fn new() -> ShmPool {
        sweep_stale_once();
        ShmPool { inner: Mutex::new(PoolInner { segs: Vec::new(), total_bytes: 0 }) }
    }

    /// Lease a segment with room for `len` bytes: best-fit recycle of
    /// a free segment, else create one within the pool bounds. `None`
    /// means the caller must fall back to the inline path (and bump
    /// `shm_fallbacks` — done in the transport so the fallback count
    /// reflects deliveries, not pool internals).
    pub(crate) fn acquire(&self, len: usize) -> Option<ShmSlot> {
        if !cfg!(unix) {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        // Best fit: smallest free segment that holds `len`.
        let mut best: Option<(usize, usize)> = None; // (index, cap)
        for (i, s) in inner.segs.iter().enumerate() {
            if s.free && s.cap >= len && best.map(|(_, c)| s.cap < c).unwrap_or(true) {
                best = Some((i, s.cap));
            }
        }
        let best = best.map(|(i, _)| i);
        let idx = match best {
            Some(i) => i,
            None => {
                let cap = len.div_ceil(CAP_GRAIN).max(1) * CAP_GRAIN;
                if inner.segs.len() >= MAX_SEGMENTS || inner.total_bytes + cap > MAX_TOTAL_BYTES {
                    return None;
                }
                let seg = match create_segment(cap) {
                    Ok(seg) => seg,
                    Err(e) => {
                        // One line per pool, not per payload: the
                        // fallback counter carries the running tally.
                        static WARNED: AtomicBool = AtomicBool::new(false);
                        if !WARNED.swap(true, Ordering::Relaxed) {
                            eprintln!("wilkins: shm segment creation failed ({e}); large payloads fall back to the socket path");
                        }
                        return None;
                    }
                };
                Ctr::ShmSegments.bump(1);
                inner.total_bytes += cap;
                inner.segs.push(seg);
                inner.segs.len() - 1
            }
        };
        let seg = &mut inner.segs[idx];
        seg.free = false;
        Some(ShmSlot {
            seg_id: seg.id,
            name: segment_name(seg.id),
            cap: seg.cap,
            map: Arc::clone(&seg.map),
        })
    }

    /// Credit an ack: the consumer dropped its last view of `seg_id`,
    /// so the segment may be rewritten. Unknown ids are ignored (a
    /// defensive stance — acks ride the same ordered link as data, so
    /// in practice they always match).
    pub(crate) fn ack(&self, seg_id: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(seg) = inner.segs.iter_mut().find(|s| s.id == seg_id) {
            seg.free = true;
        }
    }

    /// Segments currently leased out (descriptor sent, no ack yet).
    pub fn in_flight(&self) -> usize {
        self.inner.lock().unwrap().segs.iter().filter(|s| !s.free).count()
    }

    /// Segments this pool has created.
    pub fn segments(&self) -> usize {
        self.inner.lock().unwrap().segs.len()
    }
}

impl Default for ShmPool {
    fn default() -> ShmPool {
        ShmPool::new()
    }
}

impl Drop for ShmPool {
    fn drop(&mut self) {
        let inner = self.inner.lock().unwrap();
        for seg in &inner.segs {
            let _ = std::fs::remove_file(&seg.path);
        }
    }
}

fn create_segment(cap: usize) -> Result<Segment> {
    let id = NEXT_SEG.fetch_add(1, Ordering::Relaxed);
    let path = shm_dir().join(segment_name(id));
    let file = OpenOptions::new()
        .read(true)
        .write(true)
        .create_new(true)
        .open(&path)
        .map_err(|e| {
            crate::error::WilkinsError::Comm(format!("create {}: {e}", path.display()))
        })?;
    if let Err(e) = file.set_len(cap as u64) {
        let _ = std::fs::remove_file(&path);
        return Err(crate::error::WilkinsError::Comm(format!(
            "size {} to {cap} B: {e}",
            path.display()
        )));
    }
    let map = match ShmMap::map(&file, cap, true) {
        Ok(m) => Arc::new(m),
        Err(e) => {
            let _ = std::fs::remove_file(&path);
            return Err(e);
        }
    };
    Ok(Segment { id, path, map, cap, free: true })
}

/// A leased producer segment, ready to carry one payload.
pub(crate) struct ShmSlot {
    pub(crate) seg_id: u64,
    pub(crate) name: String,
    pub(crate) cap: usize,
    map: Arc<ShmMap>,
}

impl ShmSlot {
    /// Copy `bytes` into the segment — the *one* user-space copy the
    /// shm delivery pays (metered like every other wire-path memcpy).
    pub(crate) fn write(&self, bytes: &[u8]) {
        assert!(bytes.len() <= self.cap, "shm slot overflow");
        // Safety: the slot owns the segment until its descriptor's ack
        // returns, so no reader observes this write in progress; the
        // mapping is valid for `cap` bytes.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), self.map.ptr, bytes.len());
        }
        buf::note_copied(bytes.len());
    }
}

// ---------------------------------------------------------------------------
// Consumer-side delivery
// ---------------------------------------------------------------------------

/// Consumer-side backing for one shm delivery: a view of the mapped
/// segment plus the ack hook. When the last [`Payload`] view of the
/// delivery drops, Drop stages a `K_SHM_ACK` on the producer link —
/// the existing `wk-io` thread flushes it, so reclamation adds no
/// threads.
///
/// [`Payload`]: crate::comm::buf::Payload
pub(crate) struct ShmDelivery {
    pub(crate) map: Arc<ShmMap>,
    pub(crate) len: usize,
    pub(crate) seg_id: u64,
    pub(crate) writer: Arc<super::io::FrameWriter>,
}

impl ByteRegion for ShmDelivery {
    fn as_bytes(&self) -> &[u8] {
        &self.map.as_slice()[..self.len]
    }
}

impl Drop for ShmDelivery {
    fn drop(&mut self) {
        let body = super::proto::encode_shm_ack(self.seg_id);
        if super::io::on_io_thread() {
            // Sink teardown drops unread envelopes on the I/O thread
            // itself, which must never take a blocking lock. A missed
            // try_lock here forfeits the credit — at teardown the
            // producer pool is moments from dropping anyway.
            let _ = self.writer.try_stage(super::proto::K_SHM_ACK, &body);
        } else {
            // Rank-thread drop (the normal case): the ack stages and
            // wakes the I/O thread like any other small frame. A dead
            // link means the producer is gone and reclamation is moot —
            // ignore the error.
            let _ = self.writer.send_parts(super::proto::K_SHM_ACK, &[&body]);
        }
    }
}

// ---------------------------------------------------------------------------
// Stale-segment sweep
// ---------------------------------------------------------------------------

/// Unlink `wk-shm-<pid>-*` files whose owning process is gone (Linux:
/// `/proc/<pid>` missing). Runs once per process, from the first pool.
fn sweep_stale_once() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        if !cfg!(target_os = "linux") {
            return;
        }
        let dir = shm_dir();
        let Ok(entries) = std::fs::read_dir(&dir) else { return };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(rest) = name.strip_prefix("wk-shm-") else { continue };
            let Some(pid) = rest.split('-').next().and_then(|p| p.parse::<u32>().ok()) else {
                continue;
            };
            if pid == std::process::id() {
                continue;
            }
            if !Path::new(&format!("/proc/{pid}")).exists() {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_acked_segments() {
        let pool = Arc::new(ShmPool::new());
        let a = pool.acquire(100 * 1024).expect("segment");
        assert_eq!(pool.in_flight(), 1);
        let id = a.seg_id;
        drop(a);
        // Not acked yet: a second acquire of the same size must not
        // reuse the in-flight segment.
        let b = pool.acquire(100 * 1024).expect("segment");
        assert_ne!(b.seg_id, id);
        pool.ack(id);
        let c = pool.acquire(64 * 1024).expect("segment");
        assert_eq!(c.seg_id, id, "acked segment is recycled best-fit");
        assert_eq!(pool.segments(), 2);
    }

    #[test]
    fn pool_bounds_cap_segment_count() {
        let pool = Arc::new(ShmPool::new());
        let mut slots = Vec::new();
        for _ in 0..MAX_SEGMENTS {
            slots.push(pool.acquire(4096).expect("segment within bounds"));
        }
        assert!(pool.acquire(4096).is_none(), "pool must refuse past MAX_SEGMENTS");
        assert_eq!(pool.segments(), MAX_SEGMENTS);
    }

    #[test]
    fn write_then_open_roundtrips_bytes() {
        let pool = Arc::new(ShmPool::new());
        let slot = pool.acquire(80 * 1024).expect("segment");
        let data: Vec<u8> = (0..80 * 1024).map(|i| (i % 251) as u8).collect();
        slot.write(&data);
        let map = open_map(&slot.name, slot.cap).expect("consumer map");
        assert_eq!(&map.as_slice()[..data.len()], &data[..]);
    }

    #[test]
    fn pool_drop_unlinks_segment_files() {
        let pool = Arc::new(ShmPool::new());
        let slot = pool.acquire(4096).expect("segment");
        let path = shm_dir().join(&slot.name);
        assert!(path.exists());
        drop(slot);
        drop(pool);
        assert!(!path.exists(), "segment file must be unlinked on pool drop");
    }

    #[test]
    fn open_map_rejects_truncated_segment() {
        let pool = Arc::new(ShmPool::new());
        let slot = pool.acquire(4096).expect("segment");
        assert!(open_map(&slot.name, slot.cap + 4096).is_err());
    }
}
