//! Deterministic fault injection for the multi-process substrate.
//!
//! The verification suite (`rust/tests/faults.rs`) and the CI chaos
//! smoke need to make workers die, wedge, stall, and misbehave *on
//! cue*. This module is the one seam they drive: a [`FaultPlan`]
//! parsed from the `WILKINS_FAULT` environment variable (or built
//! directly in tests) that the worker serve loop consults at its
//! protocol edges. With the variable unset the plan is empty and
//! every hook is a no-op — production behavior is untouched.
//!
//! Grammar (`;`-separated directives):
//!
//! ```text
//! WILKINS_FAULT="kind@worker[:key=value,...][;...]"
//! ```
//!
//! | kind        | effect at the worker's control seam                    |
//! |-------------|--------------------------------------------------------|
//! | `kill`      | drop the control + mesh connections abruptly (or       |
//! |             | `process::exit(9)` when `WILKINS_FAULT_HARD=1`)        |
//! | `wedge`     | stop heartbeating and go silent without closing        |
//! | `delay`     | sleep `ms=N` before serving the command                |
//! | `dup-done`  | send the `InstanceDone` reply twice                    |
//! | `drop-done` | run the instance but suppress the reply, then wedge    |
//!
//! Every directive takes `after=N` (default 0): fire on the
//! (N+1)-th `RunInstance` this worker receives. Example: kill worker
//! 1 on its second instance, delay worker 2's first by 50 ms:
//!
//! ```text
//! WILKINS_FAULT="kill@1:after=1;delay@2:ms=50"
//! ```
//!
//! `at=launch` retargets a directive at the `LaunchWorld` seam
//! instead of `RunInstance` (the default, also spellable
//! `at=instance`), so `process-per-node` worlds can lose a worker
//! mid-launch:
//!
//! ```text
//! WILKINS_FAULT="kill@0:at=launch"
//! ```

use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Result, WilkinsError};

/// What a triggered directive does to the worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Die abruptly: close the control connection (and the process,
    /// under `WILKINS_FAULT_HARD=1`) without any goodbye.
    Kill,
    /// Go silent: stop heartbeating and never answer again, but keep
    /// the connection open — the "wedged peer" a plain EOF check
    /// cannot detect.
    Wedge,
    /// Sleep this many milliseconds before serving the command.
    Delay(u64),
    /// Serve the instance, then send the `InstanceDone` reply twice.
    DupDone,
    /// Serve the instance but suppress the reply, then wedge: work
    /// completed, acknowledgement lost — the case idempotency keys
    /// exist for.
    DropDone,
}

/// Which protocol seam a directive fires at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FaultAt {
    /// On a `RunInstance` receipt (ensemble dispatch; the default).
    Instance,
    /// On a `LaunchWorld` receipt (`process-per-node` launch).
    Launch,
}

/// One parsed `kind@worker[:k=v,...]` directive.
#[derive(Debug, Clone, Copy)]
struct Directive {
    worker: usize,
    kind: FaultKind,
    /// Fire on the (after+1)-th command at the `at` seam.
    after: u64,
    at: FaultAt,
}

/// A worker's fault schedule: which directives target it and how many
/// commands it has served. Shared with the worker's heartbeat thread,
/// so "stop beating" is one atomic flag away.
#[derive(Debug, Default)]
pub struct FaultPlan {
    directives: Vec<Directive>,
    /// RunInstance commands this worker has received so far.
    seen: AtomicU64,
    /// LaunchWorld commands this worker has received so far (the
    /// `at=launch` seam counts separately).
    seen_launch: AtomicU64,
    /// Set once a Wedge/DropDone fires: the heartbeat thread checks
    /// it and falls silent.
    silenced: std::sync::atomic::AtomicBool,
}

impl FaultPlan {
    /// The empty plan: every hook is a no-op.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Parse a `WILKINS_FAULT` value. Empty input is the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut directives = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            directives.push(parse_directive(part)?);
        }
        Ok(FaultPlan { directives, ..FaultPlan::default() })
    }

    /// The plan the environment prescribes for this process (empty
    /// unless `WILKINS_FAULT` is set). A malformed value is a hard
    /// error: a chaos test with a typo'd fault spec must fail loudly,
    /// not run green without injecting anything.
    pub fn from_env() -> Result<FaultPlan> {
        match std::env::var("WILKINS_FAULT") {
            Ok(v) if !v.trim().is_empty() => FaultPlan::parse(&v),
            _ => Ok(FaultPlan::none()),
        }
    }

    /// Does any directive target `worker` at all? (Lets tests assert
    /// their spec reached the right process.)
    pub fn targets(&self, worker: usize) -> bool {
        self.directives.iter().any(|d| d.worker == worker)
    }

    /// Called by the worker on each `RunInstance` receipt: returns the
    /// directive that fires now, if any. Counts the command either
    /// way.
    pub fn on_run_instance(&self, worker: usize) -> Option<FaultKind> {
        let n = self.seen.fetch_add(1, Ordering::SeqCst);
        self.fire(worker, FaultAt::Instance, n)
    }

    /// Called by the worker on each `LaunchWorld` receipt: returns the
    /// `at=launch` directive that fires now, if any. Counts the
    /// command either way (independently of the instance counter).
    pub fn on_launch_world(&self, worker: usize) -> Option<FaultKind> {
        let n = self.seen_launch.fetch_add(1, Ordering::SeqCst);
        self.fire(worker, FaultAt::Launch, n)
    }

    fn fire(&self, worker: usize, at: FaultAt, n: u64) -> Option<FaultKind> {
        let kind = self
            .directives
            .iter()
            .find(|d| d.worker == worker && d.at == at && d.after == n)
            .map(|d| d.kind);
        if matches!(kind, Some(FaultKind::Wedge) | Some(FaultKind::DropDone)) {
            self.silenced.store(true, Ordering::SeqCst);
        }
        kind
    }

    /// Has a fired directive silenced this worker (heartbeats must
    /// stop)?
    pub fn silenced(&self) -> bool {
        self.silenced.load(Ordering::SeqCst)
    }

    /// Silence the worker directly (used by kill emulation in
    /// threaded tests, where there is no process to exit).
    pub fn silence(&self) {
        self.silenced.store(true, Ordering::SeqCst);
    }
}

fn parse_directive(part: &str) -> Result<Directive> {
    let bad = |why: &str| {
        WilkinsError::Config(format!("bad WILKINS_FAULT directive `{part}`: {why}"))
    };
    let (head, opts) = match part.split_once(':') {
        Some((h, o)) => (h, Some(o)),
        None => (part, None),
    };
    let (kind_s, worker_s) = head
        .split_once('@')
        .ok_or_else(|| bad("expected `kind@worker`"))?;
    let worker: usize = worker_s
        .trim()
        .parse()
        .map_err(|_| bad("worker id must be an integer"))?;
    let mut after = 0u64;
    let mut ms: Option<u64> = None;
    let mut at = FaultAt::Instance;
    if let Some(opts) = opts {
        for kv in opts.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = kv.split_once('=').ok_or_else(|| bad("options are `key=value`"))?;
            match k.trim() {
                "after" => {
                    after = v.trim().parse().map_err(|_| bad("after must be an integer"))?;
                }
                "ms" => {
                    ms = Some(v.trim().parse().map_err(|_| bad("ms must be an integer"))?);
                }
                "at" => {
                    at = match v.trim() {
                        "instance" => FaultAt::Instance,
                        "launch" => FaultAt::Launch,
                        _ => return Err(bad("at must be `instance` or `launch`")),
                    };
                }
                other => return Err(bad(&format!("unknown option `{other}`"))),
            }
        }
    }
    let kind = match kind_s.trim() {
        "kill" => FaultKind::Kill,
        "wedge" => FaultKind::Wedge,
        "delay" => FaultKind::Delay(ms.ok_or_else(|| bad("delay needs ms=N"))?),
        "dup-done" => FaultKind::DupDone,
        "drop-done" => FaultKind::DropDone,
        other => return Err(bad(&format!("unknown fault kind `{other}`"))),
    };
    Ok(Directive { worker, kind, after, at })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_no_op() {
        let plan = FaultPlan::parse("").unwrap();
        assert!(!plan.targets(0));
        assert_eq!(plan.on_run_instance(0), None);
        assert!(!plan.silenced());
    }

    #[test]
    fn kill_fires_on_scheduled_command() {
        let plan = FaultPlan::parse("kill@1:after=1").unwrap();
        assert!(plan.targets(1));
        assert_eq!(plan.on_run_instance(1), None); // command 0
        assert_eq!(plan.on_run_instance(1), Some(FaultKind::Kill)); // command 1
        assert_eq!(plan.on_run_instance(1), None); // command 2
    }

    #[test]
    fn directives_only_hit_their_worker() {
        let plan = FaultPlan::parse("delay@2:ms=50").unwrap();
        assert_eq!(plan.on_run_instance(0), None);
        let plan2 = FaultPlan::parse("delay@2:ms=50").unwrap();
        assert_eq!(plan2.on_run_instance(2), Some(FaultKind::Delay(50)));
    }

    #[test]
    fn wedge_and_drop_done_silence_heartbeats() {
        let plan = FaultPlan::parse("wedge@0").unwrap();
        assert!(!plan.silenced());
        assert_eq!(plan.on_run_instance(0), Some(FaultKind::Wedge));
        assert!(plan.silenced());

        let plan = FaultPlan::parse("drop-done@3").unwrap();
        assert_eq!(plan.on_run_instance(3), Some(FaultKind::DropDone));
        assert!(plan.silenced());
    }

    #[test]
    fn launch_seam_counts_separately_from_instances() {
        let plan = FaultPlan::parse("kill@0:at=launch").unwrap();
        // Instance receipts never trip a launch-seam directive...
        assert_eq!(plan.on_run_instance(0), None);
        assert_eq!(plan.on_run_instance(0), None);
        // ...and the first LaunchWorld does, regardless of how many
        // instances came first.
        assert_eq!(plan.on_launch_world(0), Some(FaultKind::Kill));
        assert_eq!(plan.on_launch_world(0), None);

        // The default seam is untouched by launches.
        let plan = FaultPlan::parse("kill@0").unwrap();
        assert_eq!(plan.on_launch_world(0), None);
        assert_eq!(plan.on_run_instance(0), Some(FaultKind::Kill));
    }

    #[test]
    fn multiple_directives_parse() {
        let plan = FaultPlan::parse("kill@1:after=1; dup-done@0 ;delay@2:ms=5,after=3").unwrap();
        assert!(plan.targets(0) && plan.targets(1) && plan.targets(2));
    }

    #[test]
    fn malformed_specs_error() {
        for bad in [
            "kill",             // no @worker
            "kill@x",           // non-numeric worker
            "explode@1",        // unknown kind
            "delay@1",          // delay without ms
            "kill@1:after=abc", // non-numeric after
            "kill@1:nope=3",    // unknown option
            "kill@1:at=boot",   // unknown seam
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }
}
