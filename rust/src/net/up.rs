//! `wilkins up` on a plain workflow: run one workflow as a distributed
//! world across a freshly spawned worker pool, then aggregate exactly
//! what the single-process path aggregates.
//!
//! Placement is process-per-node ([`rendezvous::assign_nodes`]): whole
//! task instances are dealt round-robin onto workers, so a node's
//! restricted-world traffic stays on in-process mailboxes while
//! channel traffic between coupled tasks crosses the socket mesh —
//! the paper's task-per-node deployment shape. Per-task step counts
//! and transfer totals are invariant under placement: each message is
//! sent by exactly one process, so summing the per-worker counters
//! reproduces the single-process totals.

use std::path::PathBuf;
use std::time::Instant;

use crate::config::WorkflowConfig;
use crate::coordinator::report::{self, RankOutcome};
use crate::coordinator::RunReport;
use crate::error::{Result, WilkinsError};
use crate::graph::WorkflowGraph;

use super::pool::{HeartbeatConfig, WorkerPool};
use super::proto::LaunchWorld;
use super::rendezvous;

/// Options shared by the distributed run paths.
pub struct UpOpts {
    /// Requested pool width; clamped to the node count (a worker with
    /// no ranks would only idle in the mesh).
    pub workers: usize,
    pub time_scale: f64,
    pub workdir: Option<PathBuf>,
    /// AOT artifacts dir; workers attach an engine only when it holds
    /// a manifest.
    pub artifacts: Option<PathBuf>,
    /// Liveness cadence for the pool's control links and the workers'
    /// peer mesh.
    pub heartbeat: HeartbeatConfig,
}

/// Run `config_src` as one distributed world over `opts.workers`
/// processes and return the merged [`RunReport`].
pub fn run_workflow_distributed(config_src: &str, opts: &UpOpts) -> Result<RunReport> {
    let cfg = WorkflowConfig::from_yaml_str(config_src)?;
    let graph = WorkflowGraph::build(&cfg)?;
    let nworkers = opts.workers.clamp(1, graph.nodes.len());
    let owner_of = rendezvous::assign_nodes(&graph, nworkers);

    // One shared workdir for every process: same precedence as the
    // single-process driver (explicit > workflow `workdir:` > temp),
    // resolved once here so no worker falls back to a per-pid default.
    let workdir = opts
        .workdir
        .clone()
        .or_else(|| cfg.workdir.clone().map(PathBuf::from))
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("wilkins-up-{}", std::process::id()))
        });

    let pool = WorkerPool::spawn_with(nworkers, opts.heartbeat)?;
    let hb = pool.heartbeat();
    let msg = LaunchWorld {
        config_src: config_src.to_string(),
        workdir: workdir.display().to_string(),
        artifacts: opts
            .artifacts
            .as_ref()
            .map(|p| p.display().to_string())
            .unwrap_or_default(),
        time_scale: opts.time_scale,
        total_ranks: graph.total_ranks as u64,
        endpoints: pool.peer_addrs().to_vec(),
        owner_of,
        heartbeat_ms: if hb.enabled() { hb.interval.as_millis() as u64 } else { 0 },
        heartbeat_deadline_ms: hb.deadline.as_millis() as u64,
    };

    let t0 = Instant::now();
    let replies = pool.launch_world(&msg)?;
    let elapsed = t0.elapsed();

    let mut outcomes: Vec<RankOutcome> = Vec::with_capacity(graph.total_ranks);
    let mut bytes_sent = 0u64;
    let mut msgs_sent = 0u64;
    for (wid, reply) in replies.iter().enumerate() {
        if !reply.error.is_empty() {
            return Err(WilkinsError::Task(format!(
                "worker {wid} failed: {}",
                reply.error
            )));
        }
        bytes_sent += reply.bytes_sent;
        msgs_sent += reply.msgs_sent;
        for o in &reply.outcomes {
            outcomes.push(RankOutcome {
                node: o.node as usize,
                stats: o.stats.clone(),
                error: if o.error.is_empty() { None } else { Some(o.error.clone()) },
            });
        }
    }
    if outcomes.len() != graph.total_ranks {
        return Err(WilkinsError::Task(format!(
            "workers reported {} rank outcomes, world has {}",
            outcomes.len(),
            graph.total_ranks
        )));
    }
    let mut report = report::build(&graph, outcomes, elapsed, bytes_sent, msgs_sent)?;
    report.faults.heartbeat_misses = pool.heartbeat_misses();
    pool.shutdown();
    Ok(report)
}
