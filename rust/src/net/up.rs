//! `wilkins up` on a plain workflow: run one workflow as a distributed
//! world across a freshly spawned worker pool, then aggregate exactly
//! what the single-process path aggregates.
//!
//! Placement is process-per-node ([`rendezvous::assign_nodes`]): whole
//! task instances are dealt round-robin onto workers, so a node's
//! restricted-world traffic stays on in-process mailboxes while
//! channel traffic between coupled tasks crosses the socket mesh —
//! the paper's task-per-node deployment shape. Per-task step counts
//! and transfer totals are invariant under placement: each message is
//! sent by exactly one process, so summing the per-worker counters
//! reproduces the single-process totals.
//!
//! Observability: every `WorldDone` ships back the worker's structured
//! spans stamped on the worker's run-relative clock, and the pool's
//! telemetry store holds a clock-offset estimate per worker — so the
//! traced variant returns a [`DistTrace`] whose per-worker tracks can
//! be shifted onto the coordinator clock and merged into one
//! Chrome-trace timeline.

use std::path::PathBuf;
use std::time::Instant;

use crate::config::WorkflowConfig;
use crate::coordinator::report::{self, RankOutcome};
use crate::coordinator::RunReport;
use crate::error::{Result, WilkinsError};
use crate::graph::WorkflowGraph;
use crate::obs::Span;

use super::pool::{HeartbeatConfig, WorkerPool};
use super::proto::LaunchWorld;
use super::rendezvous;

/// Options shared by the distributed run paths.
pub struct UpOpts {
    /// Requested pool width; clamped to the node count (a worker with
    /// no ranks would only idle in the mesh).
    pub workers: usize,
    pub time_scale: f64,
    pub workdir: Option<PathBuf>,
    /// AOT artifacts dir; workers attach an engine only when it holds
    /// a manifest.
    pub artifacts: Option<PathBuf>,
    /// Liveness cadence for the pool's control links and the workers'
    /// peer mesh.
    pub heartbeat: HeartbeatConfig,
}

/// One worker's slice of a distributed run's trace.
pub struct WorkerTrack {
    /// Worker id (the Chrome-trace process id).
    pub worker: usize,
    /// Estimated shift from this worker's clock onto the coordinator
    /// clock (add to every span time when merging). Zero when no
    /// clock sample arrived.
    pub offset_s: f64,
    /// The worker's structured spans, on the *worker's* clock.
    pub spans: Vec<Span>,
}

/// The merged-trace raw material from one distributed run: one track
/// per worker, each with its clock-offset estimate.
#[derive(Default)]
pub struct DistTrace {
    /// Per-worker tracks, in worker-id order.
    pub tracks: Vec<WorkerTrack>,
}

/// Run `config_src` as one distributed world over `opts.workers`
/// processes and return the merged [`RunReport`].
pub fn run_workflow_distributed(config_src: &str, opts: &UpOpts) -> Result<RunReport> {
    run_workflow_distributed_traced(config_src, opts).map(|(report, _)| report)
}

/// [`run_workflow_distributed`], also returning the per-worker span
/// tracks + clock offsets that the `--trace` exporter merges.
pub fn run_workflow_distributed_traced(
    config_src: &str,
    opts: &UpOpts,
) -> Result<(RunReport, DistTrace)> {
    let cfg = WorkflowConfig::from_yaml_str(config_src)?;
    let graph = WorkflowGraph::build(&cfg)?;
    let nworkers = opts.workers.clamp(1, graph.nodes.len());
    let pool = WorkerPool::spawn_with(nworkers, opts.heartbeat)?;
    let out = run_workflow_distributed_on(&pool, config_src, opts)?;
    pool.shutdown();
    Ok(out)
}

/// Run `config_src` as one distributed world over an *existing* pool
/// (spawned by the caller — possibly of emulated in-thread workers, as
/// the fault tests do) and return the merged report + trace. Does not
/// shut the pool down; the caller owns its lifecycle.
pub fn run_workflow_distributed_on(
    pool: &WorkerPool,
    config_src: &str,
    opts: &UpOpts,
) -> Result<(RunReport, DistTrace)> {
    let cfg = WorkflowConfig::from_yaml_str(config_src)?;
    let graph = WorkflowGraph::build(&cfg)?;
    let owner_of = rendezvous::assign_nodes(&graph, pool.size());

    // One shared workdir for every process: same precedence as the
    // single-process driver (explicit > workflow `workdir:` > temp),
    // resolved once here so no worker falls back to a per-pid default.
    let workdir = opts
        .workdir
        .clone()
        .or_else(|| cfg.workdir.clone().map(PathBuf::from))
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("wilkins-up-{}", std::process::id()))
        });

    let hb = pool.heartbeat();
    let msg = LaunchWorld {
        config_src: config_src.to_string(),
        workdir: workdir.display().to_string(),
        artifacts: opts
            .artifacts
            .as_ref()
            .map(|p| p.display().to_string())
            .unwrap_or_default(),
        time_scale: opts.time_scale,
        total_ranks: graph.total_ranks as u64,
        endpoints: pool.peer_addrs().to_vec(),
        owner_of,
        heartbeat_ms: if hb.enabled() { hb.interval.as_millis() as u64 } else { 0 },
        heartbeat_deadline_ms: hb.deadline.as_millis() as u64,
    };

    let t0 = Instant::now();
    let replies = pool.launch_world(&msg)?;
    let elapsed = t0.elapsed();

    let mut outcomes: Vec<RankOutcome> = Vec::with_capacity(graph.total_ranks);
    let mut bytes_sent = 0u64;
    let mut msgs_sent = 0u64;
    let mut trace = DistTrace::default();
    for (wid, reply) in replies.iter().enumerate() {
        if !reply.error.is_empty() {
            return Err(WilkinsError::Task(format!(
                "worker {wid} failed: {}",
                reply.error
            )));
        }
        bytes_sent += reply.bytes_sent;
        msgs_sent += reply.msgs_sent;
        for o in &reply.outcomes {
            outcomes.push(RankOutcome {
                node: o.node as usize,
                stats: o.stats.clone(),
                error: if o.error.is_empty() { None } else { Some(o.error.clone()) },
            });
        }
        trace.tracks.push(WorkerTrack {
            worker: wid,
            offset_s: pool.clock_offset_s(wid).unwrap_or(0.0),
            spans: reply.spans.clone(),
        });
    }
    if outcomes.len() != graph.total_ranks {
        return Err(WilkinsError::Task(format!(
            "workers reported {} rank outcomes, world has {}",
            outcomes.len(),
            graph.total_ranks
        )));
    }
    let mut report = report::build(&graph, outcomes, elapsed, bytes_sent, msgs_sent)?;
    report.faults.heartbeat_misses = pool.heartbeat_misses();
    report.telemetry = pool.telemetry_summary();
    Ok((report, trace))
}
