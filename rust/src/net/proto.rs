//! Control-plane protocol of the multi-process substrate.
//!
//! Every message is the body of one [`codec`](super::codec) frame,
//! encoded with the same [`wire`](crate::comm::wire) writer/reader the
//! in-process LowFive protocol uses. Three conversations share the
//! frame space:
//!
//! * **rendezvous** (worker ⇄ coordinator): `Hello` (magic + version +
//!   worker id + peer endpoint) answered implicitly by the first
//!   command frame; `Shutdown` ends the session.
//! * **commands** (coordinator → worker): `LaunchWorld` joins this
//!   worker's ranks to a distributed workflow run (answered by
//!   `WorldDone`); `RunInstance` runs one whole ensemble instance in
//!   this worker process (answered by `InstanceDone`).
//! * **data plane** (worker ⇄ worker): `PeerHello` identifies a mesh
//!   link; `Data` carries one comm envelope (dst, src, comm id, tag,
//!   payload) — the socket serialization of
//!   [`Transport::deliver`](crate::comm::Transport::deliver).

use std::time::Duration;

use crate::comm::buf::{self, Payload};
use crate::comm::wire::{Reader, Writer};
use crate::coordinator::{NodeReport, RunReport};
use crate::error::{Result, WilkinsError};
use crate::lowfive::VolStats;
use crate::metrics::{Span, SpanKind};

/// Frame magic ("WLKN") — the first field of every `Hello`, so a
/// stray connection (wrong port, wrong program) fails the handshake
/// instead of desyncing the stream.
pub const MAGIC: u32 = 0x574C_4B4E;
/// Protocol version; bumped on any wire-visible change (v2: flow
/// counters in stats/reports, chunked data frames, stall spans; v3:
/// routed data plane's bytes_shared/bytes_copied counters in stats
/// and reports; v4: pooled data plane's alloc_rounds/bytes_pooled
/// counters in stats and reports; v5: heartbeat frames, idempotency
/// keys on RunInstance/InstanceDone, heartbeat intervals in
/// LaunchWorld, fault counters in run reports; v6: telemetry frames,
/// registry-driven stats encoding with durations as nanoseconds,
/// spans with key=value attrs, worker spans + clock sample on
/// WorldDone; v7: shared-memory payload plane — `K_DATA_SHM`
/// descriptor frames and `K_SHM_ACK` segment reclamation credits).
pub const VERSION: u32 = 7;

// Frame kinds.
pub const K_HELLO: u8 = 1;
pub const K_LAUNCH_WORLD: u8 = 2;
pub const K_WORLD_DONE: u8 = 3;
pub const K_RUN_INSTANCE: u8 = 4;
pub const K_INSTANCE_DONE: u8 = 5;
pub const K_SHUTDOWN: u8 = 6;
pub const K_PEER_HELLO: u8 = 7;
pub const K_DATA: u8 = 8;
/// One bounded piece of a large data envelope (see [`ChunkAssembler`]).
pub const K_DATA_CHUNK: u8 = 9;
/// Liveness beacon ([`Heartbeat`]): carries no command, only proves
/// the sender is alive. Receivers refresh their liveness clock and
/// never surface it to callers.
pub const K_HEARTBEAT: u8 = 10;
/// Periodic worker telemetry
/// ([`TelemetrySample`](crate::obs::TelemetrySample)): cumulative
/// counter snapshot + clock sample, riding the heartbeat cadence.
/// Like heartbeats, telemetry frames refresh liveness and are skimmed
/// by receive loops, never surfaced to callers.
pub const K_TELEMETRY: u8 = 11;
/// Shared-memory data envelope ([`ShmDesc`]): the payload bytes sit in
/// a mapped shm segment; the socket carries only this small
/// descriptor. Same delivery semantics as `K_DATA`, minus the two
/// kernel copies (see [`shm`](super::shm)).
pub const K_DATA_SHM: u8 = 12;
/// Segment reclamation credit: the consumer dropped its last view of
/// a shm delivery, so the producer may rewrite that segment.
pub const K_SHM_ACK: u8 = 13;

/// Periodic liveness beacon. Workers beat on their control socket so
/// the coordinator can tell "busy for a long time" from "dead or
/// wedged"; mesh peers beat on every link so idle pumps notice a
/// vanished worker instead of blocking forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Heartbeat {
    /// Sender's worker id.
    pub worker_id: u64,
    /// Monotonic per-sender beat counter (diagnostics only).
    pub seq: u64,
}

impl Heartbeat {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u64(self.worker_id);
        w.put_u64(self.seq);
        w.into_vec()
    }

    pub fn decode(body: &[u8]) -> Result<Heartbeat> {
        let mut r = Reader::new(body);
        Ok(Heartbeat { worker_id: r.get_u64()?, seq: r.get_u64()? })
    }
}

/// Worker → coordinator handshake.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    pub worker_id: u64,
    /// Endpoint of this worker's peer-mesh listener.
    pub peer_addr: String,
}

impl Hello {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u32(MAGIC);
        w.put_u32(VERSION);
        w.put_u64(self.worker_id);
        w.put_str(&self.peer_addr);
        w.into_vec()
    }

    pub fn decode(body: &[u8]) -> Result<Hello> {
        let mut r = Reader::new(body);
        let magic = r.get_u32()?;
        if magic != MAGIC {
            return Err(WilkinsError::Comm(format!(
                "bad handshake magic {magic:#x} (expected {MAGIC:#x})"
            )));
        }
        let version = r.get_u32()?;
        if version != VERSION {
            return Err(WilkinsError::Comm(format!(
                "protocol version mismatch: peer speaks {version}, we speak {VERSION}"
            )));
        }
        Ok(Hello { worker_id: r.get_u64()?, peer_addr: r.get_str()? })
    }
}

/// Coordinator → worker: join a distributed workflow run.
///
/// The worker rebuilds the graph from `config_src` (graph construction
/// and communicator-id allocation are deterministic, so every process
/// independently derives identical restricted worlds), connects the
/// peer mesh from `endpoints`, and runs the ranks `owner_of` assigns
/// to it.
#[derive(Debug, Clone, PartialEq)]
pub struct LaunchWorld {
    pub config_src: String,
    /// Shared workdir (all processes are on one host/filesystem, so
    /// file-mode transports keep working across process boundaries).
    pub workdir: String,
    /// AOT artifacts dir; empty when the workflow needs no engine.
    pub artifacts: String,
    pub time_scale: f64,
    pub total_ranks: u64,
    /// Peer-mesh endpoint per worker id.
    pub endpoints: Vec<String>,
    /// Owning worker id per global rank.
    pub owner_of: Vec<u64>,
    /// Mesh heartbeat interval in milliseconds; 0 disables mesh
    /// liveness (pumps block forever, the pre-v5 behavior).
    pub heartbeat_ms: u64,
    /// Silence on a mesh link longer than this (milliseconds) kills
    /// the link. Ignored when `heartbeat_ms` is 0.
    pub heartbeat_deadline_ms: u64,
}

impl LaunchWorld {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_str(&self.config_src);
        w.put_str(&self.workdir);
        w.put_str(&self.artifacts);
        w.put_f64(self.time_scale);
        w.put_u64(self.total_ranks);
        w.put_u64(self.endpoints.len() as u64);
        for e in &self.endpoints {
            w.put_str(e);
        }
        w.put_u64_slice(&self.owner_of);
        w.put_u64(self.heartbeat_ms);
        w.put_u64(self.heartbeat_deadline_ms);
        w.into_vec()
    }

    pub fn decode(body: &[u8]) -> Result<LaunchWorld> {
        let mut r = Reader::new(body);
        let config_src = r.get_str()?;
        let workdir = r.get_str()?;
        let artifacts = r.get_str()?;
        let time_scale = r.get_f64()?;
        let total_ranks = r.get_u64()?;
        let n = r.get_u64()? as usize;
        let mut endpoints = Vec::with_capacity(n);
        for _ in 0..n {
            endpoints.push(r.get_str()?);
        }
        let owner_of = r.get_u64_vec()?;
        let heartbeat_ms = r.get_u64()?;
        let heartbeat_deadline_ms = r.get_u64()?;
        Ok(LaunchWorld {
            config_src,
            workdir,
            artifacts,
            time_scale,
            total_ranks,
            endpoints,
            owner_of,
            heartbeat_ms,
            heartbeat_deadline_ms,
        })
    }
}

/// One rank's outcome shipped back from a worker.
#[derive(Debug, Clone)]
pub struct RankOutcomeWire {
    pub node: u64,
    pub stats: VolStats,
    /// Empty string = the rank succeeded.
    pub error: String,
}

/// Worker → coordinator: the hosted ranks finished (or the worker
/// failed to set up, in which case `error` is non-empty and
/// `outcomes` is empty).
#[derive(Debug, Clone, Default)]
pub struct WorldDone {
    pub bytes_sent: u64,
    pub msgs_sent: u64,
    pub outcomes: Vec<RankOutcomeWire>,
    pub error: String,
    /// Spans the worker's hosted ranks recorded, rebased onto the
    /// worker's run-relative clock (the coordinator shifts them by the
    /// telemetry clock offset when merging the distributed trace).
    pub spans: Vec<Span>,
    /// Seconds on the worker's run-relative clock at send time — a
    /// fallback clock sample so traces can be aligned even when the
    /// heartbeat (and with it telemetry) is disabled.
    pub t_mono_s: f64,
}

impl WorldDone {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u64(self.bytes_sent);
        w.put_u64(self.msgs_sent);
        w.put_str(&self.error);
        w.put_f64(self.t_mono_s);
        w.put_u64(self.outcomes.len() as u64);
        for o in &self.outcomes {
            w.put_u64(o.node);
            put_vol_stats(&mut w, &o.stats);
            w.put_str(&o.error);
        }
        w.put_u64(self.spans.len() as u64);
        for s in &self.spans {
            put_span(&mut w, s);
        }
        w.into_vec()
    }

    pub fn decode(body: &[u8]) -> Result<WorldDone> {
        let mut r = Reader::new(body);
        let bytes_sent = r.get_u64()?;
        let msgs_sent = r.get_u64()?;
        let error = r.get_str()?;
        let t_mono_s = r.get_f64()?;
        let n = r.get_u64()? as usize;
        let mut outcomes = Vec::with_capacity(n);
        for _ in 0..n {
            let node = r.get_u64()?;
            let stats = get_vol_stats(&mut r)?;
            let error = r.get_str()?;
            outcomes.push(RankOutcomeWire { node, stats, error });
        }
        let nspans = r.get_u64()? as usize;
        let mut spans = Vec::with_capacity(nspans);
        for _ in 0..nspans {
            spans.push(get_span(&mut r)?);
        }
        Ok(WorldDone { bytes_sent, msgs_sent, outcomes, error, spans, t_mono_s })
    }
}

/// Coordinator → worker: run one whole ensemble instance in-process
/// (the `process-per-instance` placement). The worker re-parses the
/// spec (deterministic) and picks `instance_idx`; workdir/time-scale
/// arrive pre-resolved so instance overrides and CLI flags behave
/// exactly as in the single-process path.
#[derive(Debug, Clone, PartialEq)]
pub struct RunInstance {
    pub spec_src: String,
    /// Directory `workflow:` paths in the spec resolve against.
    pub base_dir: String,
    pub instance_idx: u64,
    pub workdir: String,
    pub artifacts: String,
    pub time_scale: f64,
    /// Idempotency key, echoed verbatim in the matching
    /// [`InstanceDone`]. A re-dispatched instance reuses its key, so
    /// the coordinator can drop a stale completion from a presumed-dead
    /// worker instead of double-counting the instance.
    pub idem_key: u64,
}

impl RunInstance {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_str(&self.spec_src);
        w.put_str(&self.base_dir);
        w.put_u64(self.instance_idx);
        w.put_str(&self.workdir);
        w.put_str(&self.artifacts);
        w.put_f64(self.time_scale);
        w.put_u64(self.idem_key);
        w.into_vec()
    }

    pub fn decode(body: &[u8]) -> Result<RunInstance> {
        let mut r = Reader::new(body);
        Ok(RunInstance {
            spec_src: r.get_str()?,
            base_dir: r.get_str()?,
            instance_idx: r.get_u64()?,
            workdir: r.get_str()?,
            artifacts: r.get_str()?,
            time_scale: r.get_f64()?,
            idem_key: r.get_u64()?,
        })
    }
}

/// Worker → coordinator: one ensemble instance finished.
#[derive(Debug, Clone)]
pub struct InstanceDone {
    /// Empty string = success (then `report` is present).
    pub error: String,
    pub report: Option<RunReport>,
    /// The instance's spans on its own recorder clock (the driver
    /// shifts them onto the ensemble clock, as in-process runs do).
    pub spans: Vec<Span>,
    /// Echo of the [`RunInstance::idem_key`] this reply answers.
    pub idem_key: u64,
}

impl InstanceDone {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_str(&self.error);
        w.put_u64(self.idem_key);
        match &self.report {
            None => w.put_u8(0),
            Some(rep) => {
                w.put_u8(1);
                put_run_report(&mut w, rep);
            }
        }
        w.put_u64(self.spans.len() as u64);
        for s in &self.spans {
            put_span(&mut w, s);
        }
        w.into_vec()
    }

    pub fn decode(body: &[u8]) -> Result<InstanceDone> {
        let mut r = Reader::new(body);
        let error = r.get_str()?;
        let idem_key = r.get_u64()?;
        let report = match r.get_u8()? {
            0 => None,
            _ => Some(get_run_report(&mut r)?),
        };
        let n = r.get_u64()? as usize;
        let mut spans = Vec::with_capacity(n);
        for _ in 0..n {
            spans.push(get_span(&mut r)?);
        }
        Ok(InstanceDone { error, report, spans, idem_key })
    }
}

/// Worker ⇄ worker mesh-link handshake.
pub fn encode_peer_hello(worker_id: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u32(MAGIC);
    w.put_u64(worker_id);
    w.into_vec()
}

pub fn decode_peer_hello(body: &[u8]) -> Result<u64> {
    let mut r = Reader::new(body);
    let magic = r.get_u32()?;
    if magic != MAGIC {
        return Err(WilkinsError::Comm(format!(
            "bad peer-mesh magic {magic:#x} (expected {MAGIC:#x})"
        )));
    }
    r.get_u64()
}

/// Data-plane envelope: the socket form of one comm message
/// (concatenating legacy path — the payload is copied into the body;
/// the pooled plane sends [`encode_data_header`] + payload slices
/// with vectored writes instead).
pub fn encode_data(
    dst_global: u64,
    src_global: u64,
    comm_id: u64,
    tag: u64,
    payload: &[u8],
) -> Vec<u8> {
    let mut w = Writer::with_capacity(40 + payload.len());
    w.put_u64(dst_global);
    w.put_u64(src_global);
    w.put_u64(comm_id);
    w.put_u64(tag);
    w.put_bytes(payload);
    buf::note_copied(payload.len());
    w.into_vec()
}

/// The fixed-size head of a data envelope — everything
/// [`encode_data`] writes *before* the payload bytes, including the
/// u64 length prefix. A vectored frame write of `[header, payload]`
/// produces byte-identical wire form with zero payload copies. Built
/// on the stack: the head is 5 fixed u64s, so no buffer (pooled or
/// otherwise) is worth its traffic here.
pub fn encode_data_header(
    dst_global: u64,
    src_global: u64,
    comm_id: u64,
    tag: u64,
    payload_len: usize,
) -> [u8; 40] {
    let mut head = [0u8; 40];
    for (i, v) in [dst_global, src_global, comm_id, tag, payload_len as u64]
        .into_iter()
        .enumerate()
    {
        head[i * 8..i * 8 + 8].copy_from_slice(&v.to_le_bytes());
    }
    head
}

/// Decoded data envelope fields. The payload is a refcounted view —
/// of the receive buffer (zero-copy pooled decode) or of a copied-out
/// `Vec` (legacy decode).
pub struct DataMsg {
    pub dst_global: u64,
    pub src_global: u64,
    pub comm_id: u64,
    pub tag: u64,
    pub payload: Payload,
}

/// Legacy decode: the payload is copied out of the frame body.
pub fn decode_data(body: &[u8]) -> Result<DataMsg> {
    let mut r = Reader::new(body);
    let (dst_global, src_global, comm_id, tag) =
        (r.get_u64()?, r.get_u64()?, r.get_u64()?, r.get_u64()?);
    let bytes = r.get_bytes()?;
    buf::note_copied(bytes.len());
    Ok(DataMsg {
        dst_global,
        src_global,
        comm_id,
        tag,
        payload: Payload::copy_from_slice(bytes),
    })
}

/// Pooled decode: the payload is an O(1) slice of the frame body —
/// the bytes read off the socket reach the consumer's mailbox without
/// another copy.
pub fn decode_data_payload(body: &Payload) -> Result<DataMsg> {
    let mut r = Reader::new(body);
    Ok(DataMsg {
        dst_global: r.get_u64()?,
        src_global: r.get_u64()?,
        comm_id: r.get_u64()?,
        tag: r.get_u64()?,
        payload: r.get_bytes_sliced(body)?,
    })
}

/// Shared-memory data envelope (`K_DATA_SHM`): the same four routing
/// fields as a `K_DATA` envelope plus the segment coordinates. The
/// payload bytes never touch the socket — they sit in the named shm
/// segment, written before this descriptor is sent (the descriptor's
/// trip through the socket is the cross-process happens-before edge).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShmDesc {
    pub dst_global: u64,
    pub src_global: u64,
    pub comm_id: u64,
    pub tag: u64,
    /// Producer-side segment id, echoed back in the `K_SHM_ACK`.
    pub seg_id: u64,
    /// Payload length within the segment (bytes `0..len`).
    pub len: u64,
    /// Segment capacity — the consumer maps this many bytes.
    pub cap: u64,
    /// Segment file name, resolved against the local shm dir.
    pub name: String,
}

impl ShmDesc {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u64(self.dst_global);
        w.put_u64(self.src_global);
        w.put_u64(self.comm_id);
        w.put_u64(self.tag);
        w.put_u64(self.seg_id);
        w.put_u64(self.len);
        w.put_u64(self.cap);
        w.put_str(&self.name);
        w.into_vec()
    }

    pub fn decode(body: &[u8]) -> Result<ShmDesc> {
        let mut r = Reader::new(body);
        let d = ShmDesc {
            dst_global: r.get_u64()?,
            src_global: r.get_u64()?,
            comm_id: r.get_u64()?,
            tag: r.get_u64()?,
            seg_id: r.get_u64()?,
            len: r.get_u64()?,
            cap: r.get_u64()?,
            name: r.get_str()?,
        };
        if d.len > d.cap {
            return Err(WilkinsError::Comm(format!(
                "shm descriptor corrupt: len {} > cap {}",
                d.len, d.cap
            )));
        }
        Ok(d)
    }

    /// Decode a wiretap record of a shm delivery: the descriptor frame
    /// body followed by the captured payload image (the segment bytes
    /// the wire never carried — appended by the tap so replay stays
    /// bit-identical with shm active).
    pub fn decode_with_image(record: &[u8]) -> Result<(ShmDesc, &[u8])> {
        let mut r = Reader::new(record);
        let d = ShmDesc {
            dst_global: r.get_u64()?,
            src_global: r.get_u64()?,
            comm_id: r.get_u64()?,
            tag: r.get_u64()?,
            seg_id: r.get_u64()?,
            len: r.get_u64()?,
            cap: r.get_u64()?,
            name: r.get_str()?,
        };
        let image = &record[record.len() - r.remaining()..];
        if (image.len() as u64) < d.len {
            return Err(WilkinsError::Comm(format!(
                "shm record: payload image {} B short of descriptor len {} B",
                image.len(),
                d.len
            )));
        }
        Ok((d, &image[..d.len as usize]))
    }
}

/// `K_SHM_ACK` body: just the segment id being credited back.
pub fn encode_shm_ack(seg_id: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u64(seg_id);
    w.into_vec()
}

/// Decode a `K_SHM_ACK` body.
pub fn decode_shm_ack(body: &[u8]) -> Result<u64> {
    Reader::new(body).get_u64()
}

/// One bounded piece of a chunked data envelope (`K_DATA_CHUNK`).
///
/// Large hyperslab payloads are streamed as a sequence of chunks
/// instead of one giant frame, so a multi-GiB serve neither trips
/// [`MAX_FRAME`](super::codec::MAX_FRAME) nor monopolizes a mesh link
/// for its whole duration (the per-peer write lock is released
/// between chunks, letting other ranks' frames interleave). `seq` is
/// a per-transport message id: chunks of one message share it, and
/// chunks of concurrent messages on the same link interleave safely.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DataChunk {
    pub dst_global: u64,
    pub src_global: u64,
    pub comm_id: u64,
    pub tag: u64,
    /// Message id shared by every chunk of one envelope.
    pub seq: u64,
    /// Total payload length of the reassembled envelope.
    pub total_len: u64,
    /// This chunk's byte offset within the payload.
    pub offset: u64,
    /// This chunk's bytes: a zero-copy slice of the whole payload on
    /// the pooled path, an owned copy on the legacy path.
    pub bytes: Payload,
}

/// Concatenating legacy encode (the chunk bytes are copied into the
/// body; the pooled plane writes [`encode_data_chunk_header`] + the
/// chunk slice vectored instead).
pub fn encode_data_chunk(c: &DataChunk) -> Vec<u8> {
    let mut w = Writer::with_capacity(64 + c.bytes.len());
    w.put_u64(c.dst_global);
    w.put_u64(c.src_global);
    w.put_u64(c.comm_id);
    w.put_u64(c.tag);
    w.put_u64(c.seq);
    w.put_u64(c.total_len);
    w.put_u64(c.offset);
    w.put_bytes(&c.bytes);
    buf::note_copied(c.bytes.len());
    w.into_vec()
}

/// The fixed-size head of one chunk envelope — everything
/// [`encode_data_chunk`] writes before the chunk bytes, including the
/// u64 length prefix, so `[header, bytes]` written vectored is
/// byte-identical wire form with zero payload copies. Stack-built,
/// like [`encode_data_header`].
pub fn encode_data_chunk_header(c: &DataChunk) -> [u8; 64] {
    let mut head = [0u8; 64];
    for (i, v) in [
        c.dst_global,
        c.src_global,
        c.comm_id,
        c.tag,
        c.seq,
        c.total_len,
        c.offset,
        c.bytes.len() as u64,
    ]
    .into_iter()
    .enumerate()
    {
        head[i * 8..i * 8 + 8].copy_from_slice(&v.to_le_bytes());
    }
    head
}

/// Legacy decode: the chunk bytes are copied out of the frame body.
pub fn decode_data_chunk(body: &[u8]) -> Result<DataChunk> {
    let mut r = Reader::new(body);
    let head = decode_chunk_head(&mut r)?;
    let bytes = r.get_bytes()?;
    buf::note_copied(bytes.len());
    Ok(head.with_bytes(Payload::copy_from_slice(bytes)))
}

/// Pooled decode: the chunk bytes are an O(1) slice of the frame body.
pub fn decode_data_chunk_payload(body: &Payload) -> Result<DataChunk> {
    let mut r = Reader::new(body);
    let head = decode_chunk_head(&mut r)?;
    let bytes = r.get_bytes_sliced(body)?;
    Ok(head.with_bytes(bytes))
}

/// The seven fixed fields every chunk decode shares.
struct ChunkHead {
    dst_global: u64,
    src_global: u64,
    comm_id: u64,
    tag: u64,
    seq: u64,
    total_len: u64,
    offset: u64,
}

impl ChunkHead {
    fn with_bytes(self, bytes: Payload) -> DataChunk {
        DataChunk {
            dst_global: self.dst_global,
            src_global: self.src_global,
            comm_id: self.comm_id,
            tag: self.tag,
            seq: self.seq,
            total_len: self.total_len,
            offset: self.offset,
            bytes,
        }
    }
}

fn decode_chunk_head(r: &mut Reader) -> Result<ChunkHead> {
    Ok(ChunkHead {
        dst_global: r.get_u64()?,
        src_global: r.get_u64()?,
        comm_id: r.get_u64()?,
        tag: r.get_u64()?,
        seq: r.get_u64()?,
        total_len: r.get_u64()?,
        offset: r.get_u64()?,
    })
}

/// Split one payload into chunk envelopes of at most `chunk_size`
/// payload bytes each (at least one chunk, even for empty payloads).
/// Each chunk's bytes are an O(1) [`Payload::slice`] view — no bytes
/// move here.
pub fn chunk_payload(
    dst_global: u64,
    src_global: u64,
    comm_id: u64,
    tag: u64,
    seq: u64,
    payload: &Payload,
    chunk_size: usize,
) -> Vec<DataChunk> {
    assert!(chunk_size > 0, "chunk size must be positive");
    let total_len = payload.len() as u64;
    let mut chunks = Vec::with_capacity(payload.len() / chunk_size + 1);
    let mut offset = 0usize;
    loop {
        let end = (offset + chunk_size).min(payload.len());
        chunks.push(DataChunk {
            dst_global,
            src_global,
            comm_id,
            tag,
            seq,
            total_len,
            offset: offset as u64,
            bytes: payload
                .slice(offset..end)
                .expect("chunk bounds derive from payload len"),
        });
        offset = end;
        if offset >= payload.len() {
            return chunks;
        }
    }
}

/// The historical owned-`Vec` split (benchmark ablation arm and
/// interop reference): every chunk *copies* its bytes out of the
/// payload, exactly as the pre-pooled data plane did.
pub fn chunk_payload_owned(
    dst_global: u64,
    src_global: u64,
    comm_id: u64,
    tag: u64,
    seq: u64,
    payload: &[u8],
    chunk_size: usize,
) -> Vec<DataChunk> {
    assert!(chunk_size > 0, "chunk size must be positive");
    let total_len = payload.len() as u64;
    let mut chunks = Vec::with_capacity(payload.len() / chunk_size + 1);
    let mut offset = 0usize;
    loop {
        let end = (offset + chunk_size).min(payload.len());
        buf::note_copied(end - offset);
        chunks.push(DataChunk {
            dst_global,
            src_global,
            comm_id,
            tag,
            seq,
            total_len,
            offset: offset as u64,
            bytes: Payload::copy_from_slice(&payload[offset..end]),
        });
        offset = end;
        if offset >= payload.len() {
            return chunks;
        }
    }
}

/// Receiver-side reassembly of chunked data envelopes. One assembler
/// per pump thread; partial messages are keyed by (sender rank, seq)
/// so interleaved streams from concurrent rank threads on one mesh
/// link can never mix. Chunks of one message arrive in offset order
/// (the sender writes them sequentially onto a FIFO link).
///
/// Reassembly targets a buffer leased from the global pool, sized up
/// front from the declared total (eager preallocation capped at
/// 64 MiB): one allocation-free append per chunk at steady state, and
/// the buffer recycles once the delivered payload's last view drops.
#[derive(Default)]
pub struct ChunkAssembler {
    partial: std::collections::HashMap<(u64, u64), PartialMsg>,
}

/// One mid-reassembly message: its envelope head + the pooled buffer
/// its chunks append into.
struct PartialMsg {
    dst_global: u64,
    src_global: u64,
    comm_id: u64,
    tag: u64,
    buf: crate::comm::buf::Lease,
}

impl ChunkAssembler {
    pub fn new() -> ChunkAssembler {
        ChunkAssembler::default()
    }

    /// Messages currently mid-reassembly (observability / tests).
    pub fn in_flight(&self) -> usize {
        self.partial.len()
    }

    /// Upper bound on a reassembled payload (1 TiB): a corrupt
    /// `total_len` fails the link cleanly instead of attempting an
    /// absurd allocation — the same loud-failure stance as
    /// [`MAX_FRAME`](super::codec::MAX_FRAME), one layer up.
    pub const MAX_PAYLOAD: u64 = 1 << 40;
    /// Cap the *eager* preallocation (64 MiB); larger payloads grow
    /// incrementally so the declared length alone can't balloon RSS.
    const PREALLOC_CAP: u64 = 1 << 26;

    /// Feed one chunk; returns the completed envelope when this was
    /// the final piece.
    pub fn feed(&mut self, c: DataChunk) -> Result<Option<DataMsg>> {
        if c.total_len > Self::MAX_PAYLOAD {
            return Err(WilkinsError::Comm(format!(
                "chunk from rank {} declares a {}-byte payload (> MAX_PAYLOAD): stream desync?",
                c.src_global, c.total_len
            )));
        }
        let key = (c.src_global, c.seq);
        let entry = self.partial.entry(key).or_insert_with(|| PartialMsg {
            dst_global: c.dst_global,
            src_global: c.src_global,
            comm_id: c.comm_id,
            tag: c.tag,
            // The ablation arm must really pay the historical
            // per-message allocation, so only the pooled plane leases
            // a recycled buffer.
            buf: if buf::pooling_enabled() {
                buf::pool().lease(c.total_len.min(Self::PREALLOC_CAP) as usize)
            } else {
                crate::comm::buf::Lease::unpooled(
                    c.total_len.min(Self::PREALLOC_CAP) as usize,
                )
            },
        });
        if entry.buf.len() as u64 != c.offset {
            let got = entry.buf.len();
            self.partial.remove(&key);
            return Err(WilkinsError::Comm(format!(
                "chunk stream desync from rank {}: offset {} after {got} bytes",
                c.src_global, c.offset
            )));
        }
        entry.buf.extend_from_slice(&c.bytes);
        buf::note_copied(c.bytes.len());
        if entry.buf.len() as u64 > c.total_len {
            let got = entry.buf.len();
            self.partial.remove(&key);
            return Err(WilkinsError::Comm(format!(
                "chunk stream overflow from rank {}: {got} of {} bytes",
                c.src_global, c.total_len
            )));
        }
        if entry.buf.len() as u64 == c.total_len {
            let p = self.partial.remove(&key).expect("entry just touched");
            return Ok(Some(DataMsg {
                dst_global: p.dst_global,
                src_global: p.src_global,
                comm_id: p.comm_id,
                tag: p.tag,
                payload: p.buf.finish(),
            }));
        }
        Ok(None)
    }
}

fn put_duration(w: &mut Writer, d: Duration) {
    w.put_f64(d.as_secs_f64());
}

fn get_duration(r: &mut Reader) -> Result<Duration> {
    let s = r.get_f64()?;
    if !s.is_finite() || s < 0.0 {
        return Err(WilkinsError::Comm(format!("bad wire duration {s}")));
    }
    Ok(Duration::from_secs_f64(s))
}

// Stats ride the wire as registry-ordered u64 vectors (durations as
// nanoseconds): `VolStats::DEFS` *is* the wire layout, so a counter
// added to the family serializes without touching this file.
fn put_vol_stats(w: &mut Writer, s: &VolStats) {
    w.put_u64_slice(&s.counter_values());
}

fn get_vol_stats(r: &mut Reader) -> Result<VolStats> {
    let vals = r.get_u64_vec()?;
    if vals.len() != VolStats::DEFS.len() {
        return Err(WilkinsError::Comm(format!(
            "stats counter count mismatch: got {}, expected {}",
            vals.len(),
            VolStats::DEFS.len()
        )));
    }
    Ok(VolStats::from_counter_values(&vals))
}

fn put_fault_stats(w: &mut Writer, f: &crate::coordinator::FaultStats) {
    w.put_u64_slice(&f.counter_values());
}

fn get_fault_stats(r: &mut Reader) -> Result<crate::coordinator::FaultStats> {
    let vals = r.get_u64_vec()?;
    if vals.len() != crate::coordinator::FaultStats::DEFS.len() {
        return Err(WilkinsError::Comm(format!(
            "fault counter count mismatch: got {}, expected {}",
            vals.len(),
            crate::coordinator::FaultStats::DEFS.len()
        )));
    }
    Ok(crate::coordinator::FaultStats::from_counter_values(&vals))
}

fn put_run_report(w: &mut Writer, rep: &RunReport) {
    put_duration(w, rep.elapsed);
    w.put_u64(rep.total_ranks as u64);
    w.put_u64(rep.bytes_sent);
    w.put_u64(rep.msgs_sent);
    put_fault_stats(w, &rep.faults);
    w.put_u64(rep.nodes.len() as u64);
    for n in &rep.nodes {
        w.put_str(&n.name);
        w.put_u64(n.nprocs as u64);
        put_vol_stats(w, &n.stats);
    }
    // Telemetry is deliberately NOT on the wire: a worker-side partial
    // report has none (only the coordinator hosting a pool collects
    // it), so shipping it would only move zeros around.
}

fn get_run_report(r: &mut Reader) -> Result<RunReport> {
    let elapsed = get_duration(r)?;
    let total_ranks = r.get_u64()? as usize;
    let bytes_sent = r.get_u64()?;
    let msgs_sent = r.get_u64()?;
    let faults = get_fault_stats(r)?;
    let n = r.get_u64()? as usize;
    let mut nodes = Vec::with_capacity(n);
    for _ in 0..n {
        nodes.push(NodeReport {
            name: r.get_str()?,
            nprocs: r.get_u64()? as usize,
            stats: get_vol_stats(r)?,
        });
    }
    Ok(RunReport {
        elapsed,
        total_ranks,
        bytes_sent,
        msgs_sent,
        nodes,
        faults,
        telemetry: Default::default(),
    })
}

fn put_span(w: &mut Writer, s: &Span) {
    w.put_u64(s.rank as u64);
    w.put_u8(match s.kind {
        SpanKind::Compute => 0,
        SpanKind::Idle => 1,
        SpanKind::Transfer => 2,
        SpanKind::Stall => 3,
    });
    w.put_str(&s.label);
    w.put_f64(s.start);
    w.put_f64(s.end);
    w.put_u64(s.attrs.len() as u64);
    for (k, v) in &s.attrs {
        w.put_str(k);
        w.put_str(v);
    }
}

fn get_span(r: &mut Reader) -> Result<Span> {
    let rank = r.get_u64()? as usize;
    let kind = match r.get_u8()? {
        0 => SpanKind::Compute,
        1 => SpanKind::Idle,
        2 => SpanKind::Transfer,
        3 => SpanKind::Stall,
        k => return Err(WilkinsError::Comm(format!("bad wire span kind {k}"))),
    };
    let label = r.get_str()?;
    let start = r.get_f64()?;
    let end = r.get_f64()?;
    let nattrs = r.get_u64()? as usize;
    // Bound pathological counts the same way string/byte fields are
    // bounded: refuse anything the remaining payload cannot hold.
    if nattrs > r.remaining() {
        return Err(WilkinsError::Comm(format!("bad wire span attr count {nattrs}")));
    }
    let mut attrs = Vec::with_capacity(nattrs);
    for _ in 0..nattrs {
        attrs.push((r.get_str()?, r.get_str()?));
    }
    Ok(Span { rank, kind, label, start, end, attrs })
}
