//! Multi-process execution substrate (the layer under `comm/`).
//!
//! The paper's Wilkins runs MPI processes across cluster nodes; the
//! in-memory substrate collapses everything into rank threads of one
//! process, which serializes independent ensemble instances on one
//! core (DESIGN.md's testbed caveat). This module restores the
//! distributed shape on one host: workflow nodes and ensemble
//! instances run in separate OS processes connected over loopback
//! sockets, so multi-core machines deliver real parallelism and the
//! flat wall-clock regimes of the paper's Figures 7–10 become
//! measurable instead of simulated.
//!
//! Pieces, bottom-up:
//!
//! * [`codec`] — length-prefixed frame codec (blocking, incremental
//!   and nonblocking-restartable decode paths over the same header
//!   rules).
//! * [`poller`] — dependency-free readiness event loop: raw `epoll`
//!   on Linux, `poll(2)` elsewhere on unix, plus the wake pipe and
//!   the timer wheel the I/O thread schedules beats and deadlines on.
//! * [`proto`] — rendezvous/command/data messages, encoded with the
//!   same [`wire`](crate::comm::wire) pair as the in-process
//!   protocol.
//! * `io` (crate-private) — the per-process transport I/O thread:
//!   owns every mesh
//!   and control link's read half as a nonblocking socket on the
//!   poller, feeds decoded envelopes back into the ordinary
//!   mailbox/condvar receive path, and coalesces small outbound
//!   frames through per-link staging writers.
//! * [`transport`] — [`SocketTransport`], the socket backend of
//!   [`comm::Transport`](crate::comm::Transport): mailbox pushes for
//!   locally-hosted ranks, framed envelopes on mesh links otherwise.
//! * [`shm`] — the shared-memory payload plane for co-located
//!   processes: payloads at or above `WILKINS_SHM_MIN` cross through
//!   pooled tmpfs segments (one memcpy) while the socket carries only
//!   a descriptor frame; reclamation acks fold into the I/O thread.
//! * [`rendezvous`] — bootstrap: coordinator listener, worker join,
//!   endpoint-map exchange, deterministic peer-mesh construction, and
//!   the node → worker rank assignment.
//! * [`worker`] — the `wilkins worker` serve loop (join worlds, run
//!   ensemble instances, shut down on command).
//! * [`pool`] — [`WorkerPool`]: spawn N worker processes of the
//!   current executable and drive them.
//! * [`up`] — `wilkins up` on a workflow: one distributed world
//!   across the pool, merged into the same
//!   [`RunReport`](crate::coordinator::RunReport)
//!   (`process-per-node` placement).
//!
//! * [`faults`] — deterministic fault injection (`WILKINS_FAULT=`)
//!   driven by the verification suite and the CI chaos smoke; a
//!   no-op unless explicitly armed.
//!
//! Ensemble `process-per-instance` placement builds on the same pool
//! from [`Ensemble::run_on_pool`](crate::ensemble::Ensemble::run_on_pool).
//!
//! Liveness: every control and mesh link carries periodic
//! [`Heartbeat`](proto::Heartbeat) frames. On the worker side the
//! I/O thread's timer wheel both *sends* the beats (staged through
//! the coalescing writers) and *checks* them (per-link silence
//! deadlines), so a dead or wedged peer is detected within a
//! configurable deadline with zero dedicated threads; the
//! coordinator side keeps timed reads ([`codec::read_frame_timed`])
//! on its blocking control links (see `docs/fault-tolerance.md`).
//!
//! Everything above `comm/` — `henson::drive_rank`, `lowfive::Vol`,
//! `flow::`, collectives — runs unmodified on remote ranks: the only
//! thing that changes is where
//! [`Transport::deliver`](crate::comm::Transport::deliver) puts the
//! bytes.

pub mod codec;
pub mod faults;
pub(crate) mod io;
pub mod poller;
pub mod pool;
pub mod proto;
pub mod rendezvous;
pub mod shm;
pub mod transport;
pub mod up;
pub mod worker;

pub use faults::{FaultKind, FaultPlan};
pub use pool::{HeartbeatConfig, WorkerPool};
pub use transport::SocketTransport;
pub use up::{
    run_workflow_distributed, run_workflow_distributed_on, run_workflow_distributed_traced,
    DistTrace, UpOpts, WorkerTrack,
};
pub use worker::{worker_main, worker_main_with, WorkerOpts};

#[cfg(test)]
mod tests;
