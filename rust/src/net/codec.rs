//! Length-prefixed frame codec for the socket substrate.
//!
//! Every message on a socket — handshake, control, data envelope — is
//! one *frame*: a little-endian `u32` body length, a `u8` frame kind,
//! then the body (encoded with [`crate::comm::wire`], the same
//! writer/reader pair the in-process protocol messages use). The codec
//! is deliberately dumb: framing only, no compression, no checksums —
//! TCP/UDS already give us ordered reliable bytes, and the length
//! bound catches stream desync early.
//!
//! Send side: [`write_frame`] assembles header + body contiguously
//! (control messages, legacy path); [`write_frame_vectored`] sends
//! the header and any number of body parts with `write_vectored`
//! (`IoSlice`), so a data payload goes from the producer's encode
//! buffer straight to the kernel without a staging concatenation.
//!
//! Receive side, sharing the same header rules:
//! * [`read_frame`] — blocking, owned `Vec` body (control threads).
//! * [`read_frame_payload`] — blocking, body read into a buffer
//!   leased from the global [`buf`] pool and returned as a
//!   refcounted [`Payload`]; the data pump slices envelopes out of
//!   it with zero further copies, and the buffer recycles when the
//!   last slice drops.
//! * [`FrameDecoder`] — incremental, fed arbitrary byte slices; this
//!   is what the property tests drive with random split points to
//!   prove partial reads can never tear or reorder a frame.
//!
//! Observability: every complete frame written or read through the
//! blocking/timed paths bumps the process-global wire counters
//! ([`crate::obs::Ctr`]) and, when `WILKINS_TRACE_WIRE=1`, appends a
//! record to the per-process wire tap
//! ([`crate::obs::wiretap`]). Disabled, both cost one relaxed atomic
//! add and one `OnceLock` load per frame — `benches/wire.rs` asserts
//! the frames/sec figure is unchanged.

use std::io::{IoSlice, Read, Write};
use std::time::Instant;

use crate::comm::buf::{self, Payload};
use crate::error::{Result, WilkinsError};
use crate::obs::{wiretap, Ctr};

/// Upper bound on one frame body. Large enough for any dataset slab
/// the benches move (hundreds of MiB), small enough that a desynced
/// stream (reading payload bytes as a header) fails immediately
/// instead of attempting a multi-GiB allocation.
pub const MAX_FRAME: usize = 1 << 30;

/// Payload bytes per chunk of a chunked data envelope
/// ([`K_DATA_CHUNK`](super::proto::K_DATA_CHUNK)): payloads above
/// this stream as bounded pieces instead of one giant frame, so a
/// multi-GiB serve can cross the mesh (it would otherwise exceed
/// [`MAX_FRAME`]) and the per-peer write lock is released between
/// pieces, letting other ranks' frames interleave.
pub const CHUNK_SIZE: usize = 1 << 20;

/// Effective chunk size: [`CHUNK_SIZE`] unless `WILKINS_CHUNK_KB`
/// overrides it (read once; the value is clamped per
/// [`parse_chunk_kb`], and nonsense values are rejected loudly and
/// fall back to the default). The tunable exists so benches can sweep
/// chunking against the shm threshold without recompiling.
pub fn chunk_size() -> usize {
    static SIZE: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *SIZE.get_or_init(|| match std::env::var("WILKINS_CHUNK_KB") {
        Ok(s) => match parse_chunk_kb(&s) {
            Ok(bytes) => bytes,
            Err(e) => {
                eprintln!("wilkins: ignoring WILKINS_CHUNK_KB={s:?}: {e}; using {CHUNK_SIZE}");
                CHUNK_SIZE
            }
        },
        Err(_) => CHUNK_SIZE,
    })
}

/// Bounds for `WILKINS_CHUNK_KB`: 4 KiB keeps the chunk head (64 B)
/// amortized; 256 MiB stays under [`MAX_FRAME`] with room for heads.
pub const CHUNK_KB_MIN: usize = 4;
pub const CHUNK_KB_MAX: usize = 256 * 1024;

/// Parse a `WILKINS_CHUNK_KB` value into a byte count, clamped to
/// `[CHUNK_KB_MIN, CHUNK_KB_MAX]` KiB. Zero and non-numeric input are
/// rejected (not clamped) so a typo cannot silently reshape the wire.
pub fn parse_chunk_kb(s: &str) -> Result<usize> {
    let kb = s
        .trim()
        .parse::<u64>()
        .map_err(|_| WilkinsError::Comm(format!("chunk size {s:?} is not a whole KiB count")))?;
    if kb == 0 {
        return Err(WilkinsError::Comm("chunk size 0 would stall every envelope".into()));
    }
    Ok((kb as usize).clamp(CHUNK_KB_MIN, CHUNK_KB_MAX) * 1024)
}

/// Bytes of frame header: u32 body length + u8 kind.
pub const HEADER_LEN: usize = 5;

/// One decoded frame: kind byte + body bytes.
pub type Frame = (u8, Vec<u8>);

/// Observability note for one frame handed to the kernel: wire
/// counters + the (usually disabled) frame tap. Takes the body as
/// scattered `parts` so the tap can capture payload bytes under
/// `WILKINS_TRACE_WIRE=full` without the codec staging a copy.
#[inline]
pub(crate) fn note_tx(kind: u8, parts: &[&[u8]]) {
    let body_len: usize = parts.iter().map(|p| p.len()).sum();
    Ctr::FramesSent.bump(1);
    Ctr::BytesSentWire.bump((HEADER_LEN + body_len) as u64);
    // Shm descriptors are tapped at the shm plane itself (descriptor +
    // segment image, via `wiretap::frame_with_image`) — recording the
    // bare descriptor here would duplicate the record and strand
    // replay without the payload bytes. Counters still see the frame.
    if kind != super::proto::K_DATA_SHM {
        wiretap::frame_parts(wiretap::Dir::Tx, kind, parts);
    }
}

/// Observability note for one complete frame read off a socket.
#[inline]
fn note_rx(kind: u8, parts: &[&[u8]]) {
    let body_len: usize = parts.iter().map(|p| p.len()).sum();
    Ctr::FramesRecv.bump(1);
    Ctr::BytesRecvWire.bump((HEADER_LEN + body_len) as u64);
    // See note_tx: shm descriptors are tapped with their segment image
    // by the receiving sink, not here.
    if kind != super::proto::K_DATA_SHM {
        wiretap::frame_parts(wiretap::Dir::Rx, kind, parts);
    }
}

/// Assemble a frame as contiguous bytes (header + body). Kept separate
/// from [`write_frame`] so senders can build once and write under a
/// lock without re-encoding. This is the *concatenating* path — the
/// body is copied once here; the pooled data plane uses
/// [`write_frame_vectored`] instead.
pub fn encode_frame(kind: u8, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(body);
    buf::note_copied(body.len());
    out
}

/// Write one frame as a single `write_all` (atomic under the caller's
/// per-peer lock, so concurrent senders can never interleave frames).
pub fn write_frame<W: Write>(w: &mut W, kind: u8, body: &[u8]) -> Result<()> {
    if body.len() > MAX_FRAME {
        return Err(WilkinsError::Comm(format!(
            "frame body of {} bytes exceeds MAX_FRAME ({MAX_FRAME})",
            body.len()
        )));
    }
    w.write_all(&encode_frame(kind, body))?;
    note_tx(kind, &[body]);
    Ok(())
}

/// Write one frame whose body is scattered across `parts` without
/// concatenating: header and parts go down as one `write_vectored`
/// sequence (gather I/O). Same wire format as [`write_frame`] — only
/// the user-space copy disappears. The caller's per-peer lock must
/// cover the whole call, exactly as for `write_frame`.
pub fn write_frame_vectored<W: Write>(w: &mut W, kind: u8, parts: &[&[u8]]) -> Result<()> {
    let body_len: usize = parts.iter().map(|p| p.len()).sum();
    if body_len > MAX_FRAME {
        return Err(WilkinsError::Comm(format!(
            "frame body of {body_len} bytes exceeds MAX_FRAME ({MAX_FRAME})"
        )));
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&(body_len as u32).to_le_bytes());
    header[4] = kind;
    // write_vectored may accept any prefix of the scattered bytes;
    // loop, rebuilding the slice list past what the kernel took (one
    // reused slice buffer — partial writes must not allocate per
    // retry on a path advertised as allocation-free).
    let total = HEADER_LEN + body_len;
    let mut written = 0usize;
    let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(1 + parts.len());
    while written < total {
        slices.clear();
        let mut skip = written;
        for part in std::iter::once(&header[..]).chain(parts.iter().copied()) {
            if skip >= part.len() {
                skip -= part.len();
                continue;
            }
            slices.push(IoSlice::new(&part[skip..]));
            skip = 0;
        }
        let n = w.write_vectored(&slices)?;
        if n == 0 {
            return Err(WilkinsError::Comm(
                "socket wrote zero bytes mid-frame (peer closed?)".into(),
            ));
        }
        written += n;
    }
    note_tx(kind, parts);
    Ok(())
}

/// Read exactly one frame header; `Ok(None)` on clean EOF at the
/// frame boundary, error on EOF inside the header.
fn read_header<R: Read>(r: &mut R) -> Result<Option<(usize, u8)>> {
    let mut header = [0u8; HEADER_LEN];
    // Hand-rolled first-byte read so boundary-EOF and mid-frame EOF
    // are distinguishable.
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(WilkinsError::Comm(format!(
                    "socket closed inside a frame header ({got}/{HEADER_LEN} bytes)"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WilkinsError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
    let kind = header[4];
    if len > MAX_FRAME {
        return Err(WilkinsError::Comm(format!(
            "frame header claims {len} bytes (> MAX_FRAME): stream desync?"
        )));
    }
    Ok(Some((len, kind)))
}

/// Blocking read of one frame. `Ok(None)` on clean EOF at a frame
/// boundary (the peer closed after a complete frame); an EOF inside a
/// frame is an error (the stream died mid-message).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let Some((len, kind)) = read_header(r)? else {
        return Ok(None);
    };
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| {
        WilkinsError::Comm(format!("socket closed inside a {len}-byte frame body: {e}"))
    })?;
    note_rx(kind, &[&body[..]]);
    Ok(Some((kind, body)))
}

/// Blocking read of one frame into a buffer leased from the global
/// pool, returned as a refcounted [`Payload`]. Same EOF/desync rules
/// as [`read_frame`]. The data pump's steady state reads every frame
/// into one of a handful of recycled buffers instead of allocating a
/// `Vec` per frame.
pub fn read_frame_payload<R: Read>(r: &mut R) -> Result<Option<(u8, Payload)>> {
    let Some((len, kind)) = read_header(r)? else {
        return Ok(None);
    };
    // `take` + `read_to_end` fills the recycled buffer's spare
    // capacity directly — no zero-fill of bytes the read is about to
    // overwrite anyway.
    let mut lease = buf::pool().lease(len);
    let got = r
        .by_ref()
        .take(len as u64)
        .read_to_end(&mut lease)
        .map_err(|e| {
            WilkinsError::Comm(format!("socket closed inside a {len}-byte frame body: {e}"))
        })?;
    if got < len {
        return Err(WilkinsError::Comm(format!(
            "socket closed inside a frame body ({got}/{len} bytes)"
        )));
    }
    note_rx(kind, &[&lease[..]]);
    Ok(Some((kind, lease.finish())))
}

/// Is this io error a read-timeout tick (the socket had a read
/// timeout set and nothing arrived)? Unix reports `WouldBlock`,
/// Windows `TimedOut`; both mean "no bytes yet", not "link broken".
pub fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// One observation from a timed frame read.
#[derive(Debug)]
pub enum TimedRead<T> {
    /// A complete frame arrived.
    Frame(T),
    /// The read timeout elapsed with *zero* bytes of the next frame —
    /// the link is quiet but not desynced. Callers use these ticks to
    /// check liveness deadlines, then call again.
    Idle,
    /// Clean EOF at a frame boundary.
    Eof,
}

/// Timed read of one frame for liveness-aware receivers. The caller
/// must have armed `set_read_timeout` on the underlying stream; each
/// timeout with no bytes pending surfaces as [`TimedRead::Idle`].
///
/// Desync safety: a timeout *inside* a frame (header or body started
/// but incomplete) never returns `Idle` — dropping a half-read frame
/// would desync the stream. Instead the partial read retries in place
/// until `frame_deadline`, then errors: a peer that starts a frame
/// and stalls past the liveness deadline is wedged, not slow.
pub fn read_frame_timed<R: Read>(
    r: &mut R,
    frame_deadline: Instant,
) -> Result<TimedRead<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(TimedRead::Eof);
                }
                return Err(WilkinsError::Comm(format!(
                    "socket closed inside a frame header ({got}/{HEADER_LEN} bytes)"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                if got == 0 {
                    return Ok(TimedRead::Idle);
                }
                if Instant::now() >= frame_deadline {
                    return Err(WilkinsError::Comm(format!(
                        "peer wedged mid-frame ({got}/{HEADER_LEN} header bytes, \
                         no progress before deadline)"
                    )));
                }
            }
            Err(e) => return Err(WilkinsError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
    let kind = header[4];
    if len > MAX_FRAME {
        return Err(WilkinsError::Comm(format!(
            "frame header claims {len} bytes (> MAX_FRAME): stream desync?"
        )));
    }
    let mut body = vec![0u8; len];
    read_body_timed(r, &mut body, frame_deadline)?;
    note_rx(kind, &[&body[..]]);
    Ok(TimedRead::Frame((kind, body)))
}

/// Timed pooled read of one frame — [`read_frame_payload`] with the
/// [`read_frame_timed`] liveness rules, for the data pump. The body
/// still lands in a recycled pool buffer (zero-fill, then timed exact
/// read; the fill is the price of restartable reads).
pub fn read_frame_payload_timed<R: Read>(
    r: &mut R,
    frame_deadline: Instant,
) -> Result<TimedRead<(u8, Payload)>> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(TimedRead::Eof);
                }
                return Err(WilkinsError::Comm(format!(
                    "socket closed inside a frame header ({got}/{HEADER_LEN} bytes)"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                if got == 0 {
                    return Ok(TimedRead::Idle);
                }
                if Instant::now() >= frame_deadline {
                    return Err(WilkinsError::Comm(format!(
                        "peer wedged mid-frame ({got}/{HEADER_LEN} header bytes, \
                         no progress before deadline)"
                    )));
                }
            }
            Err(e) => return Err(WilkinsError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
    let kind = header[4];
    if len > MAX_FRAME {
        return Err(WilkinsError::Comm(format!(
            "frame header claims {len} bytes (> MAX_FRAME): stream desync?"
        )));
    }
    let mut lease = buf::pool().lease(len);
    lease.resize(len, 0);
    read_body_timed(r, &mut lease, frame_deadline)?;
    note_rx(kind, &[&lease[..]]);
    Ok(TimedRead::Frame((kind, lease.finish())))
}

/// Read exactly `buf.len()` body bytes, retrying timeout ticks until
/// `frame_deadline` (the frame has started, so giving up mid-body
/// would desync the stream — only a wedge deadline ends the wait).
fn read_body_timed<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    frame_deadline: Instant,
) -> Result<()> {
    let len = buf.len();
    let mut got = 0usize;
    while got < len {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(WilkinsError::Comm(format!(
                    "socket closed inside a frame body ({got}/{len} bytes)"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                if Instant::now() >= frame_deadline {
                    return Err(WilkinsError::Comm(format!(
                        "peer wedged mid-frame ({got}/{len} body bytes, \
                         no progress before deadline)"
                    )));
                }
            }
            Err(e) => return Err(WilkinsError::Io(e)),
        }
    }
    Ok(())
}

/// One observation from a nonblocking frame read
/// ([`NbFrameReader::read_from`]).
pub(crate) enum NbRead {
    /// A complete frame arrived.
    Frame((u8, Payload)),
    /// The socket has no more bytes right now; progress (if any) is
    /// saved — call again when the fd is readable.
    WouldBlock,
    /// Clean EOF at a frame boundary.
    Eof,
}

/// Restartable frame reader for nonblocking sockets: the event-loop
/// counterpart of [`read_frame_payload`]. Reads the header and body
/// directly into a pool-leased buffer (no intermediate staging copy),
/// suspending at any `WouldBlock` and resuming exactly where it left
/// off — a frame can be split at every byte boundary across an
/// arbitrary number of readiness events without tearing.
pub(crate) struct NbFrameReader {
    head: [u8; HEADER_LEN],
    head_got: usize,
    body: Option<buf::Lease>,
    body_got: usize,
    body_len: usize,
    kind: u8,
}

impl NbFrameReader {
    pub(crate) fn new() -> NbFrameReader {
        NbFrameReader {
            head: [0u8; HEADER_LEN],
            head_got: 0,
            body: None,
            body_got: 0,
            body_len: 0,
            kind: 0,
        }
    }

    /// Advance the in-progress frame as far as the socket allows.
    /// EOF/desync rules match the blocking readers exactly (clean EOF
    /// only at a header boundary; identical error strings), so the
    /// event loop surfaces the same diagnostics the pump threads did.
    pub(crate) fn read_from<R: Read>(&mut self, r: &mut R) -> Result<NbRead> {
        while self.body.is_none() {
            match r.read(&mut self.head[self.head_got..]) {
                Ok(0) => {
                    let got = self.head_got;
                    if got == 0 {
                        return Ok(NbRead::Eof);
                    }
                    return Err(WilkinsError::Comm(format!(
                        "socket closed inside a frame header ({got}/{HEADER_LEN} bytes)"
                    )));
                }
                Ok(n) => self.head_got += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(NbRead::WouldBlock);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(WilkinsError::Io(e)),
            }
            if self.head_got < HEADER_LEN {
                continue;
            }
            let len = u32::from_le_bytes(self.head[..4].try_into().unwrap()) as usize;
            if len > MAX_FRAME {
                return Err(WilkinsError::Comm(format!(
                    "frame header claims {len} bytes (> MAX_FRAME): stream desync?"
                )));
            }
            self.kind = self.head[4];
            // Same pooled-vs-plain split as the blocking payload path,
            // so the `--no-pool` ablation accounts identically.
            let mut lease = if buf::pooling_enabled() {
                buf::pool().lease(len)
            } else {
                buf::Lease::unpooled(len)
            };
            lease.resize(len, 0);
            self.body = Some(lease);
            self.body_got = 0;
            self.body_len = len;
        }

        // `while` (not `if`): a zero-length body must complete without
        // a read — `read(&mut [])` returning `Ok(0)` is not an EOF.
        while self.body_got < self.body_len {
            let lease = self.body.as_mut().unwrap();
            match r.read(&mut lease[self.body_got..]) {
                Ok(0) => {
                    let (got, len) = (self.body_got, self.body_len);
                    return Err(WilkinsError::Comm(format!(
                        "socket closed inside a frame body ({got}/{len} bytes)"
                    )));
                }
                Ok(n) => self.body_got += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(NbRead::WouldBlock);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(WilkinsError::Io(e)),
            }
        }

        let lease = self.body.take().unwrap();
        self.head_got = 0;
        note_rx(self.kind, &[&lease[..]]);
        Ok(NbRead::Frame((self.kind, lease.finish())))
    }
}

/// Incremental frame decoder: feed byte chunks of any size (including
/// chunks that split headers or bodies anywhere), pop complete frames.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    /// Once the staging buffer is empty, capacities above this are
    /// released: one multi-MiB burst must not pin peak-size memory in
    /// a long-lived pump forever.
    const RECLAIM_CAP: usize = 64 * 1024;

    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Append raw stream bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a complete frame.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Staging-buffer capacity (tests assert reclamation).
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are
    /// needed. Errors on a header that violates [`MAX_FRAME`].
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        if self.buf.len() < HEADER_LEN {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME {
            return Err(WilkinsError::Comm(format!(
                "frame header claims {len} bytes (> MAX_FRAME): stream desync?"
            )));
        }
        if self.buf.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let kind = self.buf[4];
        let body = self.buf[HEADER_LEN..HEADER_LEN + len].to_vec();
        buf::note_copied(len);
        self.buf.drain(..HEADER_LEN + len);
        // Reclamation: a drained buffer left over from one giant frame
        // would otherwise hold its high-water capacity for the life of
        // the pump.
        if self.buf.is_empty() && self.buf.capacity() > Self::RECLAIM_CAP {
            self.buf.shrink_to(Self::RECLAIM_CAP);
        }
        Ok(Some((kind, body)))
    }
}
