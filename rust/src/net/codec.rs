//! Length-prefixed frame codec for the socket substrate.
//!
//! Every message on a socket — handshake, control, data envelope — is
//! one *frame*: a little-endian `u32` body length, a `u8` frame kind,
//! then the body (encoded with [`crate::comm::wire`], the same
//! writer/reader pair the in-process protocol messages use). The codec
//! is deliberately dumb: framing only, no compression, no checksums —
//! TCP/UDS already give us ordered reliable bytes, and the length
//! bound catches stream desync early.
//!
//! Two decode paths share the same header rules:
//! * [`read_frame`] — blocking, for the pump and control threads
//!   (`read_exact` under the hood, clean-EOF aware).
//! * [`FrameDecoder`] — incremental, fed arbitrary byte slices; this
//!   is what the property tests drive with random split points to
//!   prove partial reads can never tear or reorder a frame.

use std::io::{Read, Write};

use crate::error::{Result, WilkinsError};

/// Upper bound on one frame body. Large enough for any dataset slab
/// the benches move (hundreds of MiB), small enough that a desynced
/// stream (reading payload bytes as a header) fails immediately
/// instead of attempting a multi-GiB allocation.
pub const MAX_FRAME: usize = 1 << 30;

/// Payload bytes per chunk of a chunked data envelope
/// ([`K_DATA_CHUNK`](super::proto::K_DATA_CHUNK)): payloads above
/// this stream as bounded pieces instead of one giant frame, so a
/// multi-GiB serve can cross the mesh (it would otherwise exceed
/// [`MAX_FRAME`]) and the per-peer write lock is released between
/// pieces, letting other ranks' frames interleave.
pub const CHUNK_SIZE: usize = 1 << 20;

/// Bytes of frame header: u32 body length + u8 kind.
pub const HEADER_LEN: usize = 5;

/// One decoded frame: kind byte + body bytes.
pub type Frame = (u8, Vec<u8>);

/// Assemble a frame as contiguous bytes (header + body). Kept separate
/// from [`write_frame`] so senders can build once and write under a
/// lock without re-encoding.
pub fn encode_frame(kind: u8, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(body);
    out
}

/// Write one frame as a single `write_all` (atomic under the caller's
/// per-peer lock, so concurrent senders can never interleave frames).
pub fn write_frame<W: Write>(w: &mut W, kind: u8, body: &[u8]) -> Result<()> {
    if body.len() > MAX_FRAME {
        return Err(WilkinsError::Comm(format!(
            "frame body of {} bytes exceeds MAX_FRAME ({MAX_FRAME})",
            body.len()
        )));
    }
    w.write_all(&encode_frame(kind, body))?;
    Ok(())
}

/// Blocking read of one frame. `Ok(None)` on clean EOF at a frame
/// boundary (the peer closed after a complete frame); an EOF inside a
/// frame is an error (the stream died mid-message).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    // Hand-rolled first-byte read so boundary-EOF and mid-frame EOF
    // are distinguishable.
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(WilkinsError::Comm(format!(
                    "socket closed inside a frame header ({got}/{HEADER_LEN} bytes)"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WilkinsError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
    let kind = header[4];
    if len > MAX_FRAME {
        return Err(WilkinsError::Comm(format!(
            "frame header claims {len} bytes (> MAX_FRAME): stream desync?"
        )));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| {
        WilkinsError::Comm(format!("socket closed inside a {len}-byte frame body: {e}"))
    })?;
    Ok(Some((kind, body)))
}

/// Incremental frame decoder: feed byte chunks of any size (including
/// chunks that split headers or bodies anywhere), pop complete frames.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Append raw stream bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a complete frame.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are
    /// needed. Errors on a header that violates [`MAX_FRAME`].
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        if self.buf.len() < HEADER_LEN {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME {
            return Err(WilkinsError::Comm(format!(
                "frame header claims {len} bytes (> MAX_FRAME): stream desync?"
            )));
        }
        if self.buf.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let kind = self.buf[4];
        let body = self.buf[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.buf.drain(..HEADER_LEN + len);
        Ok(Some((kind, body)))
    }
}
