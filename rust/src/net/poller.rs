//! A dependency-free readiness poller: the event-loop substrate under
//! [`net`](crate::net)'s single transport I/O thread.
//!
//! Three small pieces, all `std`-only:
//!
//! - [`Poller`] — register nonblocking fds with a [`Token`] and an
//!   [`Interest`], then [`Poller::wait`] for readiness [`Event`]s. On
//!   Linux it is raw `epoll` via direct syscall declarations; on other
//!   unixes it degrades to `poll(2)` over a registration list; on
//!   non-unix targets construction returns an error (the socket
//!   transport itself is unix-only today).
//! - [`Waker`] — a self-pipe that makes `wait` return from another
//!   thread (used to deliver commands to the I/O thread and to stop it).
//! - [`Timers`] — a monotonic one-shot timer wheel (binary heap with
//!   lazy cancellation) that folds heartbeat intervals, liveness
//!   deadlines and flush retries into the single `wait` timeout.
//!
//! Nothing here knows about frames or mailboxes; `net::io` composes
//! these into the actual transport loop.

use std::collections::{BinaryHeap, HashMap};
use std::io;
use std::time::{Duration, Instant};

/// Identifies a registered fd in the events returned by [`Poller::wait`].
///
/// Tokens are caller-chosen; the poller treats them as opaque payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Token(pub u64);

/// What readiness to watch a registration for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest(u8);

impl Interest {
    /// Wake when the fd has bytes to read (or hit EOF / error).
    pub const READABLE: Interest = Interest(0b01);
    /// Wake when the fd can accept writes again.
    pub const WRITABLE: Interest = Interest(0b10);

    fn readable(self) -> bool {
        self.0 & 0b01 != 0
    }
    fn writable(self) -> bool {
        self.0 & 0b10 != 0
    }
}

/// One readiness report from [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the fd was registered with.
    pub token: Token,
    /// The fd is readable (data, EOF, or a pending error — in every
    /// case the right response is to go read it).
    pub readable: bool,
    /// The peer hung up or the fd errored. Readers should still drain:
    /// a hangup can arrive with buffered bytes ahead of the EOF.
    pub hangup: bool,
}

#[cfg(unix)]
mod sys {
    //! Shared unix syscall surface: `poll(2)`, the self-pipe, fcntl.
    #![allow(non_camel_case_types)]

    use std::os::raw::{c_int, c_ulong};

    pub type RawFd = c_int;

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    // Only the poll(2)-backed poller reads these; on Linux the epoll
    // constants in `esys` cover error/hangup readiness.
    #[cfg(not(target_os = "linux"))]
    pub const POLLERR: i16 = 0x008;
    #[cfg(not(target_os = "linux"))]
    pub const POLLHUP: i16 = 0x010;

    pub const F_GETFL: c_int = 3;
    pub const F_SETFL: c_int = 4;
    pub const F_SETFD: c_int = 2;
    pub const FD_CLOEXEC: c_int = 1;
    #[cfg(target_os = "linux")]
    pub const O_NONBLOCK: c_int = 0o4000;
    #[cfg(not(target_os = "linux"))]
    pub const O_NONBLOCK: c_int = 0x4;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
        pub fn pipe(fds: *mut c_int) -> c_int;
        // Declared non-variadic with the single int arg every call
        // site here uses; fine on the supported ABIs.
        pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
        pub fn close(fd: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
    }

    /// Set (or clear) `O_NONBLOCK` on an fd.
    pub fn set_nonblocking(fd: RawFd, on: bool) -> std::io::Result<()> {
        unsafe {
            let flags = fcntl(fd, F_GETFL, 0);
            if flags < 0 {
                return Err(std::io::Error::last_os_error());
            }
            let want = if on { flags | O_NONBLOCK } else { flags & !O_NONBLOCK };
            if fcntl(fd, F_SETFL, want) < 0 {
                return Err(std::io::Error::last_os_error());
            }
        }
        Ok(())
    }

    /// Mark an fd close-on-exec (spawned workers must not inherit it).
    pub fn set_cloexec(fd: RawFd) -> std::io::Result<()> {
        unsafe {
            if fcntl(fd, F_SETFD, FD_CLOEXEC) < 0 {
                return Err(std::io::Error::last_os_error());
            }
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
mod esys {
    //! Raw `epoll` declarations (Linux only).
    use std::os::raw::c_int;

    // On x86-64 the kernel's epoll_event is packed; elsewhere it has
    // natural alignment. Matching the kernel layout exactly matters.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
            -> c_int;
    }
}

/// Round a timeout up to whole milliseconds for `epoll_wait`/`poll`.
///
/// Rounding *up* matters: rounding a 0.4 ms timer deadline down to 0
/// would spin the loop hot until the timer fires.
#[cfg(unix)]
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis();
            let ms = if d > Duration::from_millis(ms as u64) { ms + 1 } else { ms };
            ms.min(i32::MAX as u128) as i32
        }
    }
}

#[cfg(target_os = "linux")]
pub use linux_impl::Poller;

#[cfg(target_os = "linux")]
mod linux_impl {
    use super::esys::*;
    use super::sys::{self, RawFd};
    use super::{timeout_ms, Event, Interest, Token};
    use std::io;
    use std::time::Duration;

    /// Readiness poller backed by raw `epoll` syscalls.
    pub struct Poller {
        epfd: RawFd,
    }

    impl Poller {
        /// Create a new epoll instance (close-on-exec).
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        /// Watch `fd` for `interest`, reporting readiness as `token`.
        pub fn register(&self, fd: RawFd, token: Token, interest: Interest) -> io::Result<()> {
            let mut events = EPOLLERR | EPOLLHUP | EPOLLRDHUP;
            if interest.readable() {
                events |= EPOLLIN;
            }
            if interest.writable() {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events, data: token.0 };
            let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_ADD, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Stop watching `fd`.
        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            // Pre-2.6.9 kernels demand a non-null event even for DEL;
            // passing one costs nothing on modern kernels.
            let mut ev = EpollEvent { events: 0, data: 0 };
            let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Block until a registration is ready or `timeout` elapses,
        /// appending readiness reports to `events`.
        pub fn wait(&self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let mut buf = [EpollEvent { events: 0, data: 0 }; 64];
            let n = loop {
                let rc = unsafe {
                    epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms(timeout))
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
                // EINTR: retry. The loop above re-waits the full
                // timeout; timer lateness is absorbed by the caller
                // re-deriving its deadline each pass.
            };
            for ev in buf.iter().take(n) {
                // Copy the (possibly packed) fields by value before use.
                let bits = ev.events;
                let data = ev.data;
                events.push(Event {
                    token: Token(data),
                    readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                    hangup: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                sys::close(self.epfd);
            }
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
pub use poll_impl::Poller;

#[cfg(all(unix, not(target_os = "linux")))]
mod poll_impl {
    use super::sys::{self, RawFd};
    use super::{timeout_ms, Event, Interest, Token};
    use std::io;
    use std::sync::Mutex;
    use std::time::Duration;

    /// Portable fallback poller over `poll(2)` and a registration list.
    pub struct Poller {
        regs: Mutex<Vec<(RawFd, Token, Interest)>>,
    }

    impl Poller {
        /// Create an empty registration set.
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { regs: Mutex::new(Vec::new()) })
        }

        /// Watch `fd` for `interest`, reporting readiness as `token`.
        pub fn register(&self, fd: RawFd, token: Token, interest: Interest) -> io::Result<()> {
            self.regs.lock().unwrap().push((fd, token, interest));
            Ok(())
        }

        /// Stop watching `fd`.
        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.regs.lock().unwrap().retain(|(f, _, _)| *f != fd);
            Ok(())
        }

        /// Block until a registration is ready or `timeout` elapses,
        /// appending readiness reports to `events`.
        pub fn wait(&self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let regs = self.regs.lock().unwrap().clone();
            let mut fds: Vec<sys::PollFd> = regs
                .iter()
                .map(|(fd, _, interest)| {
                    let mut want = 0i16;
                    if interest.readable() {
                        want |= sys::POLLIN;
                    }
                    if interest.writable() {
                        want |= sys::POLLOUT;
                    }
                    sys::PollFd { fd: *fd, events: want, revents: 0 }
                })
                .collect();
            let n = loop {
                let rc = unsafe {
                    sys::poll(fds.as_mut_ptr(), fds.len() as _, timeout_ms(timeout))
                };
                if rc >= 0 {
                    break rc;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            if n > 0 {
                for (pfd, (_, token, _)) in fds.iter().zip(regs.iter()) {
                    if pfd.revents == 0 {
                        continue;
                    }
                    let hangup = pfd.revents & (sys::POLLERR | sys::POLLHUP) != 0;
                    events.push(Event {
                        token: *token,
                        readable: pfd.revents & sys::POLLIN != 0 || hangup,
                        hangup,
                    });
                }
            }
            Ok(())
        }
    }
}

#[cfg(not(unix))]
pub use stub_impl::Poller;

#[cfg(not(unix))]
mod stub_impl {
    use super::{Event, Interest, Token};
    use std::io;
    use std::time::Duration;

    /// Stub poller for non-unix targets: construction fails, matching
    /// the socket transport (which is unix-only today).
    pub struct Poller {}

    impl Poller {
        /// Always fails on this platform.
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "wilkins net: readiness poller is unix-only",
            ))
        }

        /// Unreachable (construction fails).
        pub fn register(&self, _fd: i32, _token: Token, _interest: Interest) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        /// Unreachable (construction fails).
        pub fn deregister(&self, _fd: i32) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        /// Unreachable (construction fails).
        pub fn wait(&self, _events: &mut Vec<Event>, _timeout: Option<Duration>) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }
    }
}

/// Self-pipe waker: lets any thread force [`Poller::wait`] to return.
///
/// Register [`Waker::read_fd`] with the poller under a reserved token;
/// [`Waker::wake`] writes one byte (coalescing with any byte already
/// buffered), and the poll loop calls [`Waker::drain`] when it sees
/// that token.
#[cfg(unix)]
pub struct Waker {
    read_fd: sys::RawFd,
    write_fd: sys::RawFd,
}

#[cfg(unix)]
impl Waker {
    /// Create the pipe pair, both ends nonblocking + close-on-exec.
    pub fn new() -> io::Result<Waker> {
        let mut fds = [0i32; 2];
        if unsafe { sys::pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        let (r, w) = (fds[0], fds[1]);
        for fd in [r, w] {
            if let Err(e) = sys::set_nonblocking(fd, true).and_then(|()| sys::set_cloexec(fd)) {
                unsafe {
                    sys::close(r);
                    sys::close(w);
                }
                return Err(e);
            }
        }
        Ok(Waker { read_fd: r, write_fd: w })
    }

    /// The readable end, for registration with the poller.
    pub fn read_fd(&self) -> sys::RawFd {
        self.read_fd
    }

    /// Make the poll loop wake. Lossy by design: if the pipe already
    /// holds an unread byte the write fails with `EAGAIN`, which is
    /// exactly the coalescing we want.
    pub fn wake(&self) {
        let b = [1u8];
        unsafe {
            sys::write(self.write_fd, b.as_ptr(), 1);
        }
    }

    /// Swallow pending wake bytes (called by the loop on its token).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { sys::read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 {
                break;
            }
        }
    }
}

#[cfg(unix)]
impl Drop for Waker {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.read_fd);
            sys::close(self.write_fd);
        }
    }
}

/// Non-unix stand-in; construction fails like the stub [`Poller`].
#[cfg(not(unix))]
pub struct Waker {}

#[cfg(not(unix))]
impl Waker {
    /// Always fails on this platform.
    pub fn new() -> io::Result<Waker> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "wilkins net: waker is unix-only",
        ))
    }

    /// Unreachable (construction fails).
    pub fn read_fd(&self) -> i32 {
        unreachable!("stub waker cannot be constructed")
    }

    /// Unreachable (construction fails).
    pub fn wake(&self) {}

    /// Unreachable (construction fails).
    pub fn drain(&self) {}
}

/// Block the calling thread until `fd` is readable (or writable when
/// `want_write`), with an optional timeout.
///
/// This is the blocking-write escape hatch: once a socket's shared
/// file description goes nonblocking for the poller, rank threads that
/// still need blocking semantics retry `WouldBlock` through here.
/// Returns `Ok(true)` when ready, `Ok(false)` on timeout.
#[cfg(unix)]
pub(crate) fn wait_fd(fd: sys::RawFd, want_write: bool, timeout: Option<Duration>) -> io::Result<bool> {
    let want = if want_write { sys::POLLOUT } else { sys::POLLIN };
    let mut pfd = sys::PollFd { fd, events: want, revents: 0 };
    loop {
        let rc = unsafe { sys::poll(&mut pfd, 1, timeout_ms(timeout)) };
        if rc > 0 {
            return Ok(true);
        }
        if rc == 0 {
            return Ok(false);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Opaque handle to an armed timer, for cancellation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimerId(u64);

/// One-shot timer wheel: a binary heap of deadlines with lazy
/// cancellation (cancelled entries are skipped when they surface).
///
/// `K` is whatever the owner wants fired — the transport loop stores
/// an enum of heartbeat / liveness / flush-retry actions.
pub struct Timers<K> {
    heap: BinaryHeap<std::cmp::Reverse<(Instant, u64)>>,
    live: HashMap<u64, K>,
    next_id: u64,
}

impl<K> Timers<K> {
    /// An empty wheel.
    pub fn new() -> Timers<K> {
        Timers { heap: BinaryHeap::new(), live: HashMap::new(), next_id: 0 }
    }

    /// Arm a one-shot timer firing `kind` at `deadline`.
    pub fn arm(&mut self, deadline: Instant, kind: K) -> TimerId {
        let id = self.next_id;
        self.next_id += 1;
        self.heap.push(std::cmp::Reverse((deadline, id)));
        self.live.insert(id, kind);
        TimerId(id)
    }

    /// Cancel an armed timer. Harmless if it already fired.
    pub fn cancel(&mut self, id: TimerId) {
        self.live.remove(&id.0);
    }

    /// The earliest live deadline, if any (prunes cancelled heads).
    pub fn next_deadline(&mut self) -> Option<Instant> {
        while let Some(std::cmp::Reverse((when, id))) = self.heap.peek().copied() {
            if self.live.contains_key(&id) {
                return Some(when);
            }
            self.heap.pop();
        }
        None
    }

    /// Pop every timer due at or before `now`, in deadline order.
    pub fn pop_expired(&mut self, now: Instant) -> Vec<K> {
        let mut fired = Vec::new();
        while let Some(std::cmp::Reverse((when, id))) = self.heap.peek().copied() {
            if when > now {
                break;
            }
            self.heap.pop();
            if let Some(kind) = self.live.remove(&id) {
                fired.push(kind);
            }
        }
        fired
    }

    /// Number of live (armed, uncancelled) timers.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    /// True when no timer is armed.
    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }
}

impl<K> Default for Timers<K> {
    fn default() -> Timers<K> {
        Timers::new()
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::{Duration, Instant};

    #[test]
    fn timers_fire_in_deadline_order_and_skip_cancelled() {
        let mut t: Timers<&'static str> = Timers::new();
        let base = Instant::now();
        let _a = t.arm(base + Duration::from_millis(30), "third");
        let b = t.arm(base + Duration::from_millis(10), "cancelled");
        let _c = t.arm(base + Duration::from_millis(20), "second");
        let _d = t.arm(base + Duration::from_millis(5), "first");
        t.cancel(b);
        assert_eq!(t.len(), 3);

        // Nothing due before the first deadline.
        assert!(t.pop_expired(base).is_empty());
        assert_eq!(t.next_deadline(), Some(base + Duration::from_millis(5)));

        // Everything due fires in deadline order, cancelled skipped.
        let fired = t.pop_expired(base + Duration::from_millis(25));
        assert_eq!(fired, vec!["first", "second"]);

        let fired = t.pop_expired(base + Duration::from_millis(60));
        assert_eq!(fired, vec!["third"]);
        assert!(t.is_empty());
        assert_eq!(t.next_deadline(), None);
    }

    #[test]
    fn waker_wakes_and_drain_clears_spurious_wakeups() {
        let poller = Poller::new().unwrap();
        let waker = Waker::new().unwrap();
        const WAKE: Token = Token(u64::MAX);
        poller.register(waker.read_fd(), WAKE, Interest::READABLE).unwrap();

        // Double-wake coalesces into (at least) one event.
        waker.wake();
        waker.wake();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == WAKE && e.readable));

        // After draining, a wait with a short timeout reports nothing:
        // the wake byte does not linger as a spurious-ready fd.
        waker.drain();
        events.clear();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "spurious wakeup after drain: {events:?}");
    }

    #[test]
    fn socket_readable_event_carries_its_token() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();
        rx.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.register(rx.as_raw_fd(), Token(7), Interest::READABLE).unwrap();

        // Nothing written yet: a short wait must time out quietly.
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty());

        tx.write_all(b"ping").unwrap();
        tx.flush().unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == Token(7) && e.readable));

        poller.deregister(rx.as_raw_fd()).unwrap();
    }
}
