//! The worker pool: N `wilkins worker` OS processes, spawned by the
//! coordinator, addressed over framed control sockets.
//!
//! The pool is placement-agnostic: `wilkins up` on a workflow uses it
//! as the host set of one distributed world
//! ([`WorkerPool::launch_world`]), while ensemble
//! `process-per-instance` placement treats it as a bank of
//! single-instance executors ([`WorkerPool::run_instance`] behind
//! [`WorkerPool::acquire`]/[`WorkerPool::release`]).

use std::process::{Child, Command, Stdio};
use std::sync::Mutex;

use crate::error::{Result, WilkinsError};

use super::proto::{self, InstanceDone, LaunchWorld, RunInstance, WorldDone};
use super::rendezvous::{Rendezvous, WorkerLink};

pub struct WorkerPool {
    links: Vec<Mutex<WorkerLink>>,
    peer_addrs: Vec<String>,
    free: Mutex<Vec<usize>>,
    children: Mutex<Vec<Child>>,
    down: Mutex<bool>,
}

impl WorkerPool {
    /// Spawn `n` workers running this very executable (`current_exe`)
    /// in `worker` mode and rendezvous with all of them. Any binary
    /// built on this crate can be a pool host as long as it routes a
    /// leading `worker` argument to [`super::worker_main`] — the
    /// `wilkins` CLI and the ensemble bench both do.
    pub fn spawn(n: usize) -> Result<WorkerPool> {
        if n == 0 {
            return Err(WilkinsError::Config("worker pool needs >= 1 worker".into()));
        }
        let rdv = Rendezvous::bind()?;
        let exe = std::env::current_exe()
            .map_err(|e| WilkinsError::Task(format!("current_exe: {e}")))?;
        let mut children = Vec::with_capacity(n);
        for id in 0..n {
            let child = Command::new(&exe)
                .arg("worker")
                .arg("--connect")
                .arg(rdv.addr())
                .arg("--id")
                .arg(id.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .map_err(|e| WilkinsError::Task(format!("spawn worker {id}: {e}")))?;
            children.push(child);
        }
        let links = rdv.accept_workers(n)?;
        let peer_addrs = links.iter().map(|l| l.peer_addr.clone()).collect();
        Ok(WorkerPool {
            links: links.into_iter().map(Mutex::new).collect(),
            peer_addrs,
            free: Mutex::new((0..n).rev().collect()),
            children: Mutex::new(children),
            down: Mutex::new(false),
        })
    }

    pub fn size(&self) -> usize {
        self.links.len()
    }

    /// Peer-mesh endpoint per worker id (the `LaunchWorld` endpoint
    /// map).
    pub fn peer_addrs(&self) -> &[String] {
        &self.peer_addrs
    }

    /// Take an idle worker id, if any.
    pub fn acquire(&self) -> Option<usize> {
        self.free.lock().unwrap().pop()
    }

    /// Return a worker id to the idle set.
    pub fn release(&self, id: usize) {
        self.free.lock().unwrap().push(id);
    }

    /// Run one ensemble instance on worker `id` (blocking round-trip;
    /// the per-link mutex keeps a worker single-tenant).
    pub fn run_instance(&self, id: usize, req: &RunInstance) -> Result<InstanceDone> {
        let mut link = self.links[id].lock().unwrap();
        link.send(proto::K_RUN_INSTANCE, &req.encode())?;
        let (kind, body) = link.recv()?;
        if kind != proto::K_INSTANCE_DONE {
            return Err(WilkinsError::Comm(format!(
                "worker {id}: expected InstanceDone, got frame kind {kind}"
            )));
        }
        InstanceDone::decode(&body)
    }

    /// Broadcast one `LaunchWorld` to every worker and collect every
    /// `WorldDone` (in worker-id order). The whole pool is one
    /// distributed world for the duration.
    pub fn launch_world(&self, msg: &LaunchWorld) -> Result<Vec<WorldDone>> {
        let body = msg.encode();
        for link in &self.links {
            link.lock().unwrap().send(proto::K_LAUNCH_WORLD, &body)?;
        }
        let mut replies = Vec::with_capacity(self.links.len());
        for link in &self.links {
            let mut link = link.lock().unwrap();
            let (kind, body) = link.recv()?;
            if kind != proto::K_WORLD_DONE {
                return Err(WilkinsError::Comm(format!(
                    "worker {}: expected WorldDone, got frame kind {kind}",
                    link.id
                )));
            }
            replies.push(WorldDone::decode(&body)?);
        }
        Ok(replies)
    }

    /// Orderly teardown: tell every worker to exit, then reap the
    /// children. Idempotent; also invoked on drop.
    pub fn shutdown(&self) {
        let mut down = self.down.lock().unwrap();
        if *down {
            return;
        }
        *down = true;
        for link in &self.links {
            let _ = link.lock().unwrap().send(proto::K_SHUTDOWN, &[]);
        }
        let mut children = self.children.lock().unwrap();
        for child in children.iter_mut() {
            let _ = child.wait();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}
