//! The worker pool: N `wilkins worker` OS processes, spawned by the
//! coordinator, addressed over framed control sockets.
//!
//! The pool is placement-agnostic: `wilkins up` on a workflow uses it
//! as the host set of one distributed world
//! ([`WorkerPool::launch_world`]), while ensemble
//! `process-per-instance` placement treats it as a bank of
//! single-instance executors ([`WorkerPool::run_instance`] behind
//! [`WorkerPool::acquire`]/[`WorkerPool::release`]).
//!
//! Liveness: workers beat on their control sockets
//! ([`proto::Heartbeat`], staged by each worker's I/O-thread timer —
//! the crate-private `net::io` module) and every pool receive is a
//! timed read, so
//! a worker that dies or wedges surfaces as
//! [`WilkinsError::WorkerLost`] within the configured deadline
//! instead of parking the coordinator forever. A lost worker is
//! marked dead and never returns to the free list; its in-flight
//! instance is the ensemble driver's to requeue.

use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{Result, WilkinsError};
use crate::obs::wiretap;
use crate::obs::{Clock, TelemetrySample, TelemetryStore, TelemetrySummary};

use super::codec::{self, TimedRead};
use super::proto::{self, InstanceDone, LaunchWorld, RunInstance, WorldDone};
use super::rendezvous::{Rendezvous, WorkerLink};

/// Heartbeat cadence of one link: how often the sender beats and how
/// much silence the receiver tolerates before declaring the peer
/// dead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeartbeatConfig {
    /// Beat period. Zero disables liveness entirely (blocking reads,
    /// the pre-v5 behavior).
    pub interval: Duration,
    /// Silence longer than this kills the link. Must be at least two
    /// intervals, or scheduling jitter alone would kill healthy
    /// links.
    pub deadline: Duration,
}

impl Default for HeartbeatConfig {
    fn default() -> HeartbeatConfig {
        HeartbeatConfig {
            interval: Duration::from_millis(250),
            deadline: Duration::from_secs(5),
        }
    }
}

impl HeartbeatConfig {
    /// No liveness: every read blocks forever (the pre-v5 contract).
    pub fn disabled() -> HeartbeatConfig {
        HeartbeatConfig { interval: Duration::ZERO, deadline: Duration::ZERO }
    }

    /// Is liveness checking on?
    pub fn enabled(&self) -> bool {
        !self.interval.is_zero()
    }

    /// Build from the YAML/CLI millisecond form, validating the
    /// deadline ≥ 2·interval invariant.
    pub fn from_millis(interval_ms: u64, deadline_ms: u64) -> Result<HeartbeatConfig> {
        if interval_ms == 0 {
            return Ok(HeartbeatConfig::disabled());
        }
        if deadline_ms < interval_ms.saturating_mul(2) {
            return Err(WilkinsError::Config(format!(
                "heartbeat deadline_ms ({deadline_ms}) must be at least twice \
                 interval_ms ({interval_ms}) or jitter alone would kill healthy links"
            )));
        }
        Ok(HeartbeatConfig {
            interval: Duration::from_millis(interval_ms),
            deadline: Duration::from_millis(deadline_ms),
        })
    }
}

pub struct WorkerPool {
    links: Vec<Mutex<WorkerLink>>,
    peer_addrs: Vec<String>,
    free: Mutex<Vec<usize>>,
    children: Mutex<Vec<Child>>,
    down: Mutex<bool>,
    heartbeat: HeartbeatConfig,
    /// Workers declared dead (closed or past-deadline silent); never
    /// handed out again.
    dead: Vec<AtomicBool>,
    /// Idle ticks where a worker went ≥ 2 intervals without traffic
    /// yet later proved alive.
    heartbeat_misses: AtomicU64,
    /// Stale `InstanceDone` replies dropped by the idempotency check.
    dup_done: AtomicU64,
    /// The coordinator's run-relative clock — the local side of every
    /// worker clock-offset sample.
    clock: Clock,
    /// Accumulated worker telemetry (counter deltas + clock samples),
    /// fed by `K_TELEMETRY` frames skimmed in [`Self::recv_live`].
    telemetry: Mutex<TelemetryStore>,
}

impl WorkerPool {
    /// Spawn `n` workers running this very executable (`current_exe`)
    /// in `worker` mode and rendezvous with all of them. Any binary
    /// built on this crate can be a pool host as long as it routes a
    /// leading `worker` argument to [`super::worker_main`] — the
    /// `wilkins` CLI and the ensemble bench both do.
    pub fn spawn(n: usize) -> Result<WorkerPool> {
        WorkerPool::spawn_with(n, HeartbeatConfig::default())
    }

    /// [`WorkerPool::spawn`] with an explicit heartbeat cadence
    /// (propagated to the workers via `--heartbeat-ms`).
    pub fn spawn_with(n: usize, heartbeat: HeartbeatConfig) -> Result<WorkerPool> {
        if n == 0 {
            return Err(WilkinsError::Config("worker pool needs >= 1 worker".into()));
        }
        let rdv = Rendezvous::bind()?;
        let exe = std::env::current_exe()
            .map_err(|e| WilkinsError::Task(format!("current_exe: {e}")))?;
        let mut children = Vec::with_capacity(n);
        for id in 0..n {
            let child = Command::new(&exe)
                .arg("worker")
                .arg("--connect")
                .arg(rdv.addr())
                .arg("--id")
                .arg(id.to_string())
                .arg("--heartbeat-ms")
                .arg(heartbeat.interval.as_millis().to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .map_err(|e| WilkinsError::Task(format!("spawn worker {id}: {e}")))?;
            children.push(child);
        }
        let links = rdv.accept_workers(n)?;
        Ok(WorkerPool::assemble(links, children, heartbeat))
    }

    /// Host a pool whose workers the *caller* launches — typically
    /// [`super::worker_main_with`] on threads of this very process,
    /// which is how the fault-injection tests run emulated workers
    /// (integration-test binaries cannot re-exec themselves in worker
    /// mode; their `main` belongs to the test harness). `launch` is
    /// called once per worker id with the rendezvous address and must
    /// get a worker connecting to it.
    pub fn host<F>(n: usize, heartbeat: HeartbeatConfig, mut launch: F) -> Result<WorkerPool>
    where
        F: FnMut(&str, usize),
    {
        if n == 0 {
            return Err(WilkinsError::Config("worker pool needs >= 1 worker".into()));
        }
        let rdv = Rendezvous::bind()?;
        for id in 0..n {
            launch(rdv.addr(), id);
        }
        let links = rdv.accept_workers(n)?;
        Ok(WorkerPool::assemble(links, Vec::new(), heartbeat))
    }

    fn assemble(
        links: Vec<WorkerLink>,
        children: Vec<Child>,
        heartbeat: HeartbeatConfig,
    ) -> WorkerPool {
        let n = links.len();
        let peer_addrs = links.iter().map(|l| l.peer_addr.clone()).collect();
        WorkerPool {
            links: links.into_iter().map(Mutex::new).collect(),
            peer_addrs,
            free: Mutex::new((0..n).rev().collect()),
            children: Mutex::new(children),
            down: Mutex::new(false),
            heartbeat,
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
            heartbeat_misses: AtomicU64::new(0),
            dup_done: AtomicU64::new(0),
            clock: Clock::new(),
            telemetry: Mutex::new(TelemetryStore::new()),
        }
    }

    pub fn size(&self) -> usize {
        self.links.len()
    }

    /// Workers not (yet) declared dead.
    pub fn alive(&self) -> usize {
        self.dead.iter().filter(|d| !d.load(Ordering::SeqCst)).count()
    }

    /// Has this worker been declared dead?
    pub fn is_dead(&self, id: usize) -> bool {
        self.dead[id].load(Ordering::SeqCst)
    }

    /// Declare a worker dead: it never returns to the free list and
    /// every subsequent `run_instance` on it fails fast.
    pub fn mark_dead(&self, id: usize) {
        self.dead[id].store(true, Ordering::SeqCst);
    }

    /// The pool's heartbeat cadence.
    pub fn heartbeat(&self) -> HeartbeatConfig {
        self.heartbeat
    }

    /// Idle ticks where a worker went ≥ 2 beat intervals silent but
    /// later proved alive (zero on a healthy pool).
    pub fn heartbeat_misses(&self) -> u64 {
        self.heartbeat_misses.load(Ordering::SeqCst)
    }

    /// Stale `InstanceDone` replies dropped by the idempotency-key
    /// check instead of being double-counted.
    pub fn dup_done(&self) -> u64 {
        self.dup_done.load(Ordering::SeqCst)
    }

    /// Condensed worker telemetry collected so far: frames ingested,
    /// workers heard from, summed counter totals. Telemetry outlives
    /// the workers that sent it — a worker lost mid-run keeps its
    /// counts here.
    pub fn telemetry_summary(&self) -> TelemetrySummary {
        self.telemetry.lock().unwrap().summary()
    }

    /// Estimated clock offset for worker `id`: a worker-clock time `t`
    /// maps onto the pool clock as `t + offset`. `None` before any
    /// telemetry frame or clock sample from that worker.
    pub fn clock_offset_s(&self, id: usize) -> Option<f64> {
        self.telemetry.lock().unwrap().offset_s(id as u64)
    }

    /// Peer-mesh endpoint per worker id (the `LaunchWorld` endpoint
    /// map).
    pub fn peer_addrs(&self) -> &[String] {
        &self.peer_addrs
    }

    /// Take an idle worker id, if any. Dead workers are skimmed off
    /// rather than handed out (a worker can die while idle).
    pub fn acquire(&self) -> Option<usize> {
        let mut free = self.free.lock().unwrap();
        while let Some(id) = free.pop() {
            if !self.is_dead(id) {
                return Some(id);
            }
        }
        None
    }

    /// Return a worker id to the idle set (dead workers stay out).
    pub fn release(&self, id: usize) {
        if !self.is_dead(id) {
            self.free.lock().unwrap().push(id);
        }
    }

    /// Receive the next *command-level* frame on `link`, skimming
    /// heartbeat and telemetry frames and enforcing the liveness
    /// deadline (telemetry frames are folded into the pool's
    /// [`TelemetryStore`] and also count as proof of life). With
    /// heartbeats disabled this is the historical blocking `recv`.
    fn recv_live(&self, link: &mut WorkerLink) -> Result<(u8, Vec<u8>)> {
        let hb = self.heartbeat;
        if !hb.enabled() {
            return link.recv();
        }
        let id = link.id;
        link.conn
            .set_read_timeout(Some(hb.interval))
            .map_err(|e| WilkinsError::Comm(format!("set_read_timeout: {e}")))?;
        // The liveness clock starts at recv entry: a worker quietly
        // idle *between* our commands owes us nothing.
        let mut last_alive = Instant::now();
        let mut missed_since_alive = 0u32;
        let out = loop {
            match codec::read_frame_timed(&mut link.conn, Instant::now() + hb.deadline) {
                Ok(TimedRead::Frame((kind, body))) => {
                    if kind == proto::K_HEARTBEAT {
                        last_alive = Instant::now();
                        missed_since_alive = 0;
                        continue;
                    }
                    if kind == proto::K_TELEMETRY {
                        last_alive = Instant::now();
                        missed_since_alive = 0;
                        if let Ok(s) = TelemetrySample::decode(&body) {
                            self.telemetry.lock().unwrap().ingest(&s, self.clock.now_s());
                        }
                        continue;
                    }
                    break Ok((kind, body));
                }
                Ok(TimedRead::Idle) => {
                    let silent = last_alive.elapsed();
                    if silent >= hb.deadline {
                        self.mark_dead(id);
                        break Err(WilkinsError::WorkerLost(format!(
                            "worker {id} missed its heartbeat deadline \
                             ({:.1}s silent, deadline {:.1}s)",
                            silent.as_secs_f64(),
                            hb.deadline.as_secs_f64()
                        )));
                    }
                    // Count each whole beat interval the worker has
                    // gone dark beyond its first (the first quiet tick
                    // is scheduling jitter, not a miss).
                    let owed = (silent.as_nanos() / hb.interval.as_nanos().max(1))
                        .saturating_sub(1) as u32;
                    if owed > missed_since_alive {
                        self.heartbeat_misses
                            .fetch_add(u64::from(owed - missed_since_alive), Ordering::SeqCst);
                        missed_since_alive = owed;
                    }
                }
                Ok(TimedRead::Eof) => {
                    self.mark_dead(id);
                    break Err(WilkinsError::WorkerLost(format!(
                        "worker {id} closed its control connection"
                    )));
                }
                Err(e) => {
                    self.mark_dead(id);
                    break Err(WilkinsError::WorkerLost(format!(
                        "worker {id} control link failed: {e}"
                    )));
                }
            }
        };
        let _ = link.conn.set_read_timeout(None);
        out
    }

    /// Run one ensemble instance on worker `id` (blocking round-trip;
    /// the per-link mutex keeps a worker single-tenant). A reply whose
    /// idempotency key is not `req.idem_key` is a stale completion
    /// from an earlier dispatch (e.g. a duplicated or delayed
    /// `InstanceDone`); it is counted and dropped, never returned.
    pub fn run_instance(&self, id: usize, req: &RunInstance) -> Result<InstanceDone> {
        if self.is_dead(id) {
            return Err(WilkinsError::WorkerLost(format!(
                "worker {id} is already marked dead"
            )));
        }
        let mut link = self.links[id].lock().unwrap();
        wiretap::set_link(id as u32);
        if let Err(e) = link.send(proto::K_RUN_INSTANCE, &req.encode()) {
            self.mark_dead(id);
            return Err(WilkinsError::WorkerLost(format!(
                "worker {id} control link failed on send: {e}"
            )));
        }
        loop {
            let (kind, body) = self.recv_live(&mut link)?;
            if kind != proto::K_INSTANCE_DONE {
                return Err(WilkinsError::Comm(format!(
                    "worker {id}: expected InstanceDone, got frame kind {kind}"
                )));
            }
            let done = InstanceDone::decode(&body)?;
            if done.idem_key != req.idem_key {
                self.dup_done.fetch_add(1, Ordering::SeqCst);
                continue;
            }
            return Ok(done);
        }
    }

    /// Broadcast one `LaunchWorld` to every worker and collect every
    /// `WorldDone` (in worker-id order). The whole pool is one
    /// distributed world for the duration.
    pub fn launch_world(&self, msg: &LaunchWorld) -> Result<Vec<WorldDone>> {
        let body = msg.encode();
        for link in &self.links {
            let mut link = link.lock().unwrap();
            // Tag this thread's wire-tap records with the worker id so
            // a replay can attribute each frame to its link.
            wiretap::set_link(link.id as u32);
            link.send(proto::K_LAUNCH_WORLD, &body)?;
        }
        let mut replies = Vec::with_capacity(self.links.len());
        for link in &self.links {
            let mut link = link.lock().unwrap();
            wiretap::set_link(link.id as u32);
            let (kind, body) = self.recv_live(&mut link)?;
            if kind != proto::K_WORLD_DONE {
                return Err(WilkinsError::Comm(format!(
                    "worker {}: expected WorldDone, got frame kind {kind}",
                    link.id
                )));
            }
            let done = WorldDone::decode(&body)?;
            // Every reply doubles as a clock sample (zero-stamped
            // error replies excluded), so even a heartbeat-disabled
            // pool can align worker spans for trace merging.
            if done.t_mono_s > 0.0 {
                self.telemetry.lock().unwrap().clock_sample(
                    link.id as u64,
                    done.t_mono_s,
                    self.clock.now_s(),
                );
            }
            replies.push(done);
        }
        Ok(replies)
    }

    /// Orderly teardown: tell every worker to exit, then reap the
    /// children. Idempotent; also invoked on drop.
    pub fn shutdown(&self) {
        let mut down = self.down.lock().unwrap();
        if *down {
            return;
        }
        *down = true;
        for link in &self.links {
            let _ = link.lock().unwrap().send(proto::K_SHUTDOWN, &[]);
        }
        let mut children = self.children.lock().unwrap();
        for (id, child) in children.iter_mut().enumerate() {
            // A dead worker never reads the Shutdown frame; waiting on
            // a wedged child would hang the teardown, so put it down.
            if self.dead.get(id).is_some_and(|d| d.load(Ordering::SeqCst)) {
                let _ = child.kill();
            }
            let _ = child.wait();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}
