//! The socket backend of [`Transport`]: local ranks get mailbox
//! pushes, remote ranks get framed envelopes on the mesh link to the
//! process that hosts them.
//!
//! Send side: `SocketTransport::deliver` routes on the global
//! `owner_of` map. Remote sends write one frame under the per-peer
//! lock — vectored (stack-built header + payload slices, no staging
//! concatenation) on the default pooled plane, the historical
//! assemble-and-`write_all` on the ablation arm — preserving the
//! in-memory backend's "buffered eager" semantics: the call returns
//! once the bytes are handed to the kernel, and frames from
//! concurrent rank threads can never interleave.
//!
//! Receive side: one pump thread per mesh link ([`spawn_pump`]) reads
//! frames (into recycled pool buffers on the pooled plane, slicing
//! envelopes out of them with zero further copies) and pushes them
//! into the shared [`Mailboxes`]; blocked `recv`s wake through the
//! ordinary mailbox condvar, so `Comm`, `InterComm`, collectives and
//! probes run unmodified on remote ranks.

use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

use crate::comm::buf::{self, Payload};
use crate::comm::{Envelope, Mailboxes, Transport};
use crate::error::{Result, WilkinsError};
use crate::obs::wiretap;

use super::codec;
use super::proto;

/// A per-peer write half. The stream is a `try_clone` of the pump's
/// read half, so dropping the transport closes the link for both.
pub(crate) struct PeerLink {
    stream: Mutex<TcpStream>,
}

impl PeerLink {
    pub(crate) fn new(stream: TcpStream) -> PeerLink {
        PeerLink { stream: Mutex::new(stream) }
    }

    fn send_frame(&self, kind: u8, body: &[u8]) -> Result<()> {
        // The MAX_FRAME bound is checked by `write_frame` before any
        // byte goes out: writing an over-bound header would make the
        // receiving pump treat the stream as desynced and kill the
        // link for every rank sharing it; failing just this send is
        // the right blast radius.
        let mut s = self.stream.lock().unwrap();
        codec::write_frame(&mut *s, kind, body)
    }

    /// Vectored frame send: header + body parts go to the kernel as
    /// one gather write under the per-peer lock — no staging
    /// concatenation of the payload. Wire-identical to `send_frame`
    /// of the concatenated parts; the MAX_FRAME bound is enforced by
    /// [`codec::write_frame_vectored`] before any byte is written, so
    /// an oversized body fails this send without desyncing the link.
    fn send_frame_vectored(&self, kind: u8, parts: &[&[u8]]) -> Result<()> {
        let mut s = self.stream.lock().unwrap();
        codec::write_frame_vectored(&mut *s, kind, parts)
    }
}

/// Socket-backed [`Transport`]: see the module docs.
pub struct SocketTransport {
    my_worker: usize,
    /// Owning worker id per global rank.
    owner_of: Vec<usize>,
    /// Mesh link per worker id (`None` at `my_worker`).
    peers: Vec<Option<PeerLink>>,
    /// Local inboxes, shared with the pump threads.
    mailboxes: Arc<Mailboxes>,
    /// Message id for chunked envelopes (shared by all rank threads).
    next_seq: AtomicU64,
}

impl SocketTransport {
    pub(crate) fn new(
        my_worker: usize,
        owner_of: Vec<usize>,
        peers: Vec<Option<PeerLink>>,
        mailboxes: Arc<Mailboxes>,
    ) -> SocketTransport {
        SocketTransport { my_worker, owner_of, peers, mailboxes, next_seq: AtomicU64::new(1) }
    }

    /// Is this global rank hosted by this process?
    pub fn hosts(&self, global_rank: usize) -> bool {
        self.owner_of[global_rank] == self.my_worker
    }

    /// Send one heartbeat frame on every mesh link (the mesh beat
    /// thread's tick). Deliberately outside the `World` send counters
    /// — liveness traffic must not perturb the transfer totals the
    /// benches and reports assert on. Send errors are ignored: a dead
    /// link is the receiving pump's diagnosis to make.
    pub(crate) fn beat_all(&self, seq: u64) {
        let beat = proto::Heartbeat { worker_id: self.my_worker as u64, seq };
        let body = beat.encode();
        for (peer, link) in self.peers.iter().enumerate() {
            let Some(link) = link else { continue };
            if wiretap::enabled() {
                wiretap::set_link(peer as u32);
            }
            let _ = link.send_frame(proto::K_HEARTBEAT, &body);
        }
    }
}

impl Transport for SocketTransport {
    fn deliver(
        &self,
        dst_global: usize,
        src_global: usize,
        comm_id: u64,
        tag: u64,
        payload: Payload,
    ) {
        let owner = self.owner_of[dst_global];
        if owner == self.my_worker {
            self.mailboxes.push(
                dst_global,
                Envelope { src_global, comm_id, tag, payload },
            );
            return;
        }
        let link = self.peers[owner]
            .as_ref()
            .unwrap_or_else(|| panic!("no mesh link to worker {owner}"));
        // Tag this rank thread's tap records with the destination link
        // (only when the tap is armed; the thread-local write is not
        // free enough for the default hot path).
        if wiretap::enabled() {
            wiretap::set_link(owner as u32);
        }
        // A dead link mid-run means the peer process crashed; the
        // send contract has no error path (MPI_Send aborts too), so
        // panic this rank thread — the driver reports it as a failed
        // rank rather than hanging the whole workflow on a recv that
        // can never complete.
        if payload.len() <= codec::CHUNK_SIZE {
            let res = if buf::pooling_enabled() {
                // Pooled plane: stack-built envelope head, payload
                // bytes gathered straight off the caller's buffer.
                let head = proto::encode_data_header(
                    dst_global as u64,
                    src_global as u64,
                    comm_id,
                    tag,
                    payload.len(),
                );
                link.send_frame_vectored(proto::K_DATA, &[head.as_slice(), payload.as_slice()])
            } else {
                // Ablation arm: the historical concatenating encode.
                let body = proto::encode_data(
                    dst_global as u64,
                    src_global as u64,
                    comm_id,
                    tag,
                    &payload,
                );
                link.send_frame(proto::K_DATA, &body)
            };
            if let Err(e) = res {
                panic!("mesh link to worker {owner} failed: {e}");
            }
            return;
        }
        // Large payload: stream bounded chunks. Each chunk takes and
        // releases the per-peer lock, so concurrent senders interleave
        // at chunk granularity; the receiving pump reassembles by
        // (sender, seq).
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        if buf::pooling_enabled() {
            // Pooled plane: each chunk is an O(1) slice of the payload,
            // written vectored after its stack-built header — the
            // payload bytes are never copied on the send side.
            for c in proto::chunk_payload(
                dst_global as u64,
                src_global as u64,
                comm_id,
                tag,
                seq,
                &payload,
                codec::CHUNK_SIZE,
            ) {
                let head = proto::encode_data_chunk_header(&c);
                if let Err(e) =
                    link.send_frame_vectored(proto::K_DATA_CHUNK, &[head.as_slice(), c.bytes.as_slice()])
                {
                    panic!("mesh link to worker {owner} failed: {e}");
                }
            }
            return;
        }
        // Ablation arm: owned chunk splits + concatenating encodes,
        // the pre-pooled data plane bit for bit.
        for c in proto::chunk_payload_owned(
            dst_global as u64,
            src_global as u64,
            comm_id,
            tag,
            seq,
            &payload,
            codec::CHUNK_SIZE,
        ) {
            let body = proto::encode_data_chunk(&c);
            if let Err(e) = link.send_frame(proto::K_DATA_CHUNK, &body) {
                panic!("mesh link to worker {owner} failed: {e}");
            }
        }
    }

    fn is_local(&self, dst_global: usize) -> bool {
        self.hosts(dst_global)
    }

    fn shutdown(&self) {
        for link in self.peers.iter().flatten() {
            let _ = link.send_frame(proto::K_SHUTDOWN, &[]);
            if let Ok(s) = link.stream.lock() {
                let _ = s.shutdown(Shutdown::Write);
            }
        }
    }
}

/// Spawn the inbound pump for one mesh link: frames in, mailbox
/// pushes out. Exits on a `Shutdown` frame, clean EOF, or any stream
/// error (a worker that died mid-run; the sender side panics with the
/// real diagnosis).
///
/// With `liveness: Some((interval, deadline))` the pump uses timed
/// reads: peers beat every `interval` (see
/// [`SocketTransport::beat_all`]), and a link silent past `deadline`
/// is declared dead — a peer that vanished without closing its
/// socket (SIGKILL mid-syscall, wedged host) no longer parks the
/// pump forever. Ranks blocked on the dead peer's data still unstick
/// via the ordinary `RECV_TIMEOUT`, now with the pump's diagnosis on
/// stderr first.
pub(crate) fn spawn_pump(
    stream: TcpStream,
    mailboxes: Arc<Mailboxes>,
    peer_id: usize,
    liveness: Option<(std::time::Duration, std::time::Duration)>,
) -> JoinHandle<()> {
    thread::Builder::new()
        .name(format!("wk-net-pump-{peer_id}"))
        .spawn(move || {
            let mut stream = stream;
            let mut assembler = proto::ChunkAssembler::new();
            // Every frame this pump reads crossed the one link it owns.
            wiretap::set_link(peer_id as u32);
            if let Some((interval, _)) = liveness {
                if stream.set_read_timeout(Some(interval)).is_err() {
                    eprintln!(
                        "wilkins net: mesh link from worker {peer_id}: cannot arm \
                         read timeout; liveness checks disabled on this link"
                    );
                }
            }
            let mut last_rx = std::time::Instant::now();
            loop {
                // Pooled plane: frames land in recycled pool buffers
                // and envelopes are sliced out of them — the bytes
                // read off the socket are the bytes the consumer
                // fills its hyperslab from. The ablation arm keeps
                // the historical owned-Vec read + copy-out decode.
                let frame = match liveness {
                    Some((_, deadline)) => {
                        let frame_deadline = std::time::Instant::now() + deadline;
                        let timed = if buf::pooling_enabled() {
                            codec::read_frame_payload_timed(&mut stream, frame_deadline)
                        } else {
                            codec::read_frame_timed(&mut stream, frame_deadline).map(|t| {
                                match t {
                                    codec::TimedRead::Frame((k, body)) => {
                                        codec::TimedRead::Frame((k, Payload::from(body)))
                                    }
                                    codec::TimedRead::Idle => codec::TimedRead::Idle,
                                    codec::TimedRead::Eof => codec::TimedRead::Eof,
                                }
                            })
                        };
                        match timed {
                            Ok(codec::TimedRead::Frame(f)) => {
                                last_rx = std::time::Instant::now();
                                Ok(Some(f))
                            }
                            Ok(codec::TimedRead::Idle) => {
                                if last_rx.elapsed() >= deadline {
                                    eprintln!(
                                        "wilkins net: mesh link from worker {peer_id} died \
                                         (silent past the {:.1}s heartbeat deadline); \
                                         ranks waiting on it will time out",
                                        deadline.as_secs_f64()
                                    );
                                    break;
                                }
                                continue;
                            }
                            Ok(codec::TimedRead::Eof) => Ok(None),
                            Err(e) => Err(e),
                        }
                    }
                    None => {
                        if buf::pooling_enabled() {
                            codec::read_frame_payload(&mut stream)
                        } else {
                            codec::read_frame(&mut stream)
                                .map(|f| f.map(|(k, body)| (k, Payload::from(body))))
                        }
                    }
                };
                match frame {
                    Ok(Some((proto::K_DATA, body))) => match decode_data_any(&body) {
                        Ok(msg) => mailboxes.push(
                            msg.dst_global as usize,
                            Envelope {
                                src_global: msg.src_global as usize,
                                comm_id: msg.comm_id,
                                tag: msg.tag,
                                payload: msg.payload,
                            },
                        ),
                        Err(e) => {
                            eprintln!(
                                "wilkins net: mesh link from worker {peer_id} died \
                                 (bad data frame: {e}); ranks waiting on it will time out"
                            );
                            break;
                        }
                    },
                    Ok(Some((proto::K_DATA_CHUNK, body))) => {
                        let complete = decode_chunk_any(&body)
                            .and_then(|c| assembler.feed(c));
                        match complete {
                            Ok(Some(msg)) => mailboxes.push(
                                msg.dst_global as usize,
                                Envelope {
                                    src_global: msg.src_global as usize,
                                    comm_id: msg.comm_id,
                                    tag: msg.tag,
                                    payload: msg.payload,
                                },
                            ),
                            Ok(None) => {} // mid-reassembly
                            Err(e) => {
                                eprintln!(
                                    "wilkins net: mesh link from worker {peer_id} died \
                                     (bad chunk: {e}); ranks waiting on it will time out"
                                );
                                break;
                            }
                        }
                    }
                    // Liveness beacon: already refreshed `last_rx`
                    // above; never surfaces to the mailboxes.
                    Ok(Some((proto::K_HEARTBEAT, _))) => {}
                    // Orderly teardown: peer signalled shutdown or
                    // closed cleanly at a frame boundary.
                    Ok(Some((proto::K_SHUTDOWN, _))) | Ok(None) => break,
                    Ok(Some((kind, _))) => {
                        eprintln!(
                            "wilkins net: mesh link from worker {peer_id} died \
                             (unexpected frame kind {kind}); ranks waiting on it will time out"
                        );
                        break;
                    }
                    Err(e) => {
                        eprintln!(
                            "wilkins net: mesh link from worker {peer_id} died ({e}); \
                             ranks waiting on it will time out"
                        );
                        break;
                    }
                }
            }
        })
        .expect("spawn net pump thread")
}

/// Decode a data envelope per the process's pooling mode: zero-copy
/// payload slice when pooled, historical copy-out otherwise.
fn decode_data_any(body: &Payload) -> Result<proto::DataMsg> {
    if buf::pooling_enabled() {
        proto::decode_data_payload(body)
    } else {
        proto::decode_data(body)
    }
}

/// Decode a chunk envelope per the process's pooling mode.
fn decode_chunk_any(body: &Payload) -> Result<proto::DataChunk> {
    if buf::pooling_enabled() {
        proto::decode_data_chunk_payload(body)
    } else {
        proto::decode_data_chunk(body)
    }
}

/// Connect + handshake helper shared by mesh building and rendezvous:
/// TCP with Nagle off (the substrate moves many small protocol
/// messages whose latency is the whole point).
pub(crate) fn connect(addr: &str) -> Result<TcpStream> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| WilkinsError::Comm(format!("connect {addr}: {e}")))?;
    stream
        .set_nodelay(true)
        .map_err(|e| WilkinsError::Comm(format!("set_nodelay: {e}")))?;
    Ok(stream)
}
