//! The socket backend of [`Transport`]: local ranks get mailbox
//! pushes, remote ranks get framed envelopes on the mesh link to the
//! process that hosts them.
//!
//! Send side: `SocketTransport::deliver` routes on the global
//! `owner_of` map. Remote sends assemble one frame and `write_all` it
//! under the per-peer lock, preserving the in-memory backend's
//! "buffered eager" semantics — the call returns once the bytes are
//! handed to the kernel, and frames from concurrent rank threads can
//! never interleave.
//!
//! Receive side: one pump thread per mesh link ([`spawn_pump`]) reads
//! frames and pushes envelopes into the shared [`Mailboxes`]; blocked
//! `recv`s wake through the ordinary mailbox condvar, so `Comm`,
//! `InterComm`, collectives and probes run unmodified on remote ranks.

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

use crate::comm::{Envelope, Mailboxes, Transport};
use crate::error::{Result, WilkinsError};

use super::codec;
use super::proto;

/// A per-peer write half. The stream is a `try_clone` of the pump's
/// read half, so dropping the transport closes the link for both.
pub(crate) struct PeerLink {
    stream: Mutex<TcpStream>,
}

impl PeerLink {
    pub(crate) fn new(stream: TcpStream) -> PeerLink {
        PeerLink { stream: Mutex::new(stream) }
    }

    fn send_frame(&self, kind: u8, body: &[u8]) -> Result<()> {
        if body.len() > codec::MAX_FRAME {
            // Writing an over-bound header would make the receiving
            // pump treat the stream as desynced and kill the link for
            // every rank sharing it; fail just this send instead.
            return Err(WilkinsError::Comm(format!(
                "frame body of {} bytes exceeds MAX_FRAME ({})",
                body.len(),
                codec::MAX_FRAME
            )));
        }
        let frame = codec::encode_frame(kind, body);
        let mut s = self.stream.lock().unwrap();
        s.write_all(&frame)?;
        Ok(())
    }
}

/// Socket-backed [`Transport`]: see the module docs.
pub struct SocketTransport {
    my_worker: usize,
    /// Owning worker id per global rank.
    owner_of: Vec<usize>,
    /// Mesh link per worker id (`None` at `my_worker`).
    peers: Vec<Option<PeerLink>>,
    /// Local inboxes, shared with the pump threads.
    mailboxes: Arc<Mailboxes>,
    /// Message id for chunked envelopes (shared by all rank threads).
    next_seq: AtomicU64,
}

impl SocketTransport {
    pub(crate) fn new(
        my_worker: usize,
        owner_of: Vec<usize>,
        peers: Vec<Option<PeerLink>>,
        mailboxes: Arc<Mailboxes>,
    ) -> SocketTransport {
        SocketTransport { my_worker, owner_of, peers, mailboxes, next_seq: AtomicU64::new(1) }
    }

    /// Is this global rank hosted by this process?
    pub fn hosts(&self, global_rank: usize) -> bool {
        self.owner_of[global_rank] == self.my_worker
    }
}

impl Transport for SocketTransport {
    fn deliver(
        &self,
        dst_global: usize,
        src_global: usize,
        comm_id: u64,
        tag: u64,
        payload: Vec<u8>,
    ) {
        let owner = self.owner_of[dst_global];
        if owner == self.my_worker {
            self.mailboxes.push(
                dst_global,
                Envelope { src_global, comm_id, tag, payload },
            );
            return;
        }
        let link = self.peers[owner]
            .as_ref()
            .unwrap_or_else(|| panic!("no mesh link to worker {owner}"));
        // A dead link mid-run means the peer process crashed; the
        // send contract has no error path (MPI_Send aborts too), so
        // panic this rank thread — the driver reports it as a failed
        // rank rather than hanging the whole workflow on a recv that
        // can never complete.
        if payload.len() <= codec::CHUNK_SIZE {
            let body = proto::encode_data(
                dst_global as u64,
                src_global as u64,
                comm_id,
                tag,
                &payload,
            );
            if let Err(e) = link.send_frame(proto::K_DATA, &body) {
                panic!("mesh link to worker {owner} failed: {e}");
            }
            return;
        }
        // Large payload: stream bounded chunks. Each chunk takes and
        // releases the per-peer lock, so concurrent senders interleave
        // at chunk granularity; the receiving pump reassembles by
        // (sender, seq).
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        for c in proto::chunk_payload(
            dst_global as u64,
            src_global as u64,
            comm_id,
            tag,
            seq,
            &payload,
            codec::CHUNK_SIZE,
        ) {
            let body = proto::encode_data_chunk(&c);
            if let Err(e) = link.send_frame(proto::K_DATA_CHUNK, &body) {
                panic!("mesh link to worker {owner} failed: {e}");
            }
        }
    }

    fn is_local(&self, dst_global: usize) -> bool {
        self.hosts(dst_global)
    }

    fn shutdown(&self) {
        for link in self.peers.iter().flatten() {
            let _ = link.send_frame(proto::K_SHUTDOWN, &[]);
            if let Ok(s) = link.stream.lock() {
                let _ = s.shutdown(Shutdown::Write);
            }
        }
    }
}

/// Spawn the inbound pump for one mesh link: frames in, mailbox
/// pushes out. Exits on a `Shutdown` frame, clean EOF, or any stream
/// error (a worker that died mid-run; the sender side panics with the
/// real diagnosis).
pub(crate) fn spawn_pump(
    stream: TcpStream,
    mailboxes: Arc<Mailboxes>,
    peer_id: usize,
) -> JoinHandle<()> {
    thread::Builder::new()
        .name(format!("wk-net-pump-{peer_id}"))
        .spawn(move || {
            let mut stream = stream;
            let mut assembler = proto::ChunkAssembler::new();
            loop {
                match codec::read_frame(&mut stream) {
                    Ok(Some((proto::K_DATA, body))) => match proto::decode_data(&body) {
                        Ok(msg) => mailboxes.push(
                            msg.dst_global as usize,
                            Envelope {
                                src_global: msg.src_global as usize,
                                comm_id: msg.comm_id,
                                tag: msg.tag,
                                payload: msg.payload,
                            },
                        ),
                        Err(e) => {
                            eprintln!(
                                "wilkins net: mesh link from worker {peer_id} died \
                                 (bad data frame: {e}); ranks waiting on it will time out"
                            );
                            break;
                        }
                    },
                    Ok(Some((proto::K_DATA_CHUNK, body))) => {
                        let complete = proto::decode_data_chunk(&body)
                            .and_then(|c| assembler.feed(c));
                        match complete {
                            Ok(Some(msg)) => mailboxes.push(
                                msg.dst_global as usize,
                                Envelope {
                                    src_global: msg.src_global as usize,
                                    comm_id: msg.comm_id,
                                    tag: msg.tag,
                                    payload: msg.payload,
                                },
                            ),
                            Ok(None) => {} // mid-reassembly
                            Err(e) => {
                                eprintln!(
                                    "wilkins net: mesh link from worker {peer_id} died \
                                     (bad chunk: {e}); ranks waiting on it will time out"
                                );
                                break;
                            }
                        }
                    }
                    // Orderly teardown: peer signalled shutdown or
                    // closed cleanly at a frame boundary.
                    Ok(Some((proto::K_SHUTDOWN, _))) | Ok(None) => break,
                    Ok(Some((kind, _))) => {
                        eprintln!(
                            "wilkins net: mesh link from worker {peer_id} died \
                             (unexpected frame kind {kind}); ranks waiting on it will time out"
                        );
                        break;
                    }
                    Err(e) => {
                        eprintln!(
                            "wilkins net: mesh link from worker {peer_id} died ({e}); \
                             ranks waiting on it will time out"
                        );
                        break;
                    }
                }
            }
        })
        .expect("spawn net pump thread")
}

/// Connect + handshake helper shared by mesh building and rendezvous:
/// TCP with Nagle off (the substrate moves many small protocol
/// messages whose latency is the whole point).
pub(crate) fn connect(addr: &str) -> Result<TcpStream> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| WilkinsError::Comm(format!("connect {addr}: {e}")))?;
    stream
        .set_nodelay(true)
        .map_err(|e| WilkinsError::Comm(format!("set_nodelay: {e}")))?;
    Ok(stream)
}
