//! The socket backend of [`Transport`]: local ranks get mailbox
//! pushes, remote ranks get framed envelopes on the mesh link to the
//! process that hosts them.
//!
//! Send side: `SocketTransport::deliver` routes on the global
//! `owner_of` map. Remote sends go through the per-peer
//! `FrameWriter` (crate-private `net::io`): small envelopes (flow
//! `Done`/credit grants and other control-sized frames) stage into
//! the writer's coalescing buffer and flush as one write at the I/O
//! thread's next loop boundary; payload-bearing frames flush the
//! stage (FIFO order per link) and write vectored — stack-built
//! header + payload slices, no staging concatenation — preserving the
//! in-memory backend's "buffered eager" semantics: the call returns
//! once the bytes are handed off, and frames from concurrent rank
//! threads can never interleave (one writer per link serializes
//! them).
//!
//! Receive side: the process's single transport I/O thread
//! (the crate-private `net::io` module) owns every mesh link's read
//! half, decodes frames
//! incrementally off nonblocking sockets (into recycled pool buffers
//! on the pooled plane, slicing envelopes out of them with zero
//! further copies) and pushes them into the shared [`Mailboxes`];
//! blocked `recv`s wake through the ordinary mailbox condvar, so
//! `Comm`, `InterComm`, collectives and probes run unmodified on
//! remote ranks. The thread-per-link pump model this replaces burned
//! O(workers²) parked threads per process.

use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::comm::buf::{self, Payload};
use crate::comm::{Envelope, Mailboxes, Transport};
use crate::error::{Result, WilkinsError};
use crate::obs::wiretap;

use super::codec;
use super::io::FrameWriter;
use super::proto;
use super::shm::{self, ShmPool};
use crate::obs::Ctr;

/// Socket-backed [`Transport`]: see the module docs.
pub struct SocketTransport {
    my_worker: usize,
    /// Owning worker id per global rank.
    owner_of: Vec<usize>,
    /// Staging writer per worker id (`None` at `my_worker`). The
    /// paired read half lives with the I/O thread.
    peers: Vec<Option<Arc<FrameWriter>>>,
    /// Local inboxes, shared with the I/O thread.
    mailboxes: Arc<Mailboxes>,
    /// Message id for chunked envelopes (shared by all rank threads).
    next_seq: AtomicU64,
    /// Producer-side shm segments (shared with the I/O thread's sinks,
    /// which credit segments back as `K_SHM_ACK`s arrive).
    shm_pool: Arc<ShmPool>,
}

impl SocketTransport {
    pub(crate) fn new(
        my_worker: usize,
        owner_of: Vec<usize>,
        peers: Vec<Option<Arc<FrameWriter>>>,
        mailboxes: Arc<Mailboxes>,
        shm_pool: Arc<ShmPool>,
    ) -> SocketTransport {
        SocketTransport {
            my_worker,
            owner_of,
            peers,
            mailboxes,
            next_seq: AtomicU64::new(1),
            shm_pool,
        }
    }

    /// Is this global rank hosted by this process?
    pub fn hosts(&self, global_rank: usize) -> bool {
        self.owner_of[global_rank] == self.my_worker
    }

    /// Stage one heartbeat frame on every mesh link (the I/O thread's
    /// mesh-beat timer tick). Deliberately outside the `World` send
    /// counters — liveness traffic must not perturb the transfer
    /// totals the benches and reports assert on. `try_stage` may skip
    /// a contended link: contention means a rank thread is actively
    /// writing, which is itself proof of life. A dead link is the
    /// receiving side's diagnosis to make.
    pub(crate) fn beat_all_staged(&self, seq: u64) {
        let beat = proto::Heartbeat { worker_id: self.my_worker as u64, seq };
        let body = beat.encode();
        for (peer, w) in self.peers.iter().enumerate() {
            let Some(w) = w else { continue };
            if wiretap::enabled() {
                wiretap::set_link(peer as u32);
            }
            let _ = w.try_stage(proto::K_HEARTBEAT, &body);
        }
    }
}

impl Transport for SocketTransport {
    fn deliver(
        &self,
        dst_global: usize,
        src_global: usize,
        comm_id: u64,
        tag: u64,
        payload: Payload,
    ) {
        let owner = self.owner_of[dst_global];
        if owner == self.my_worker {
            self.mailboxes.push(
                dst_global,
                Envelope { src_global, comm_id, tag, payload },
            );
            return;
        }
        let w = self.peers[owner]
            .as_ref()
            .unwrap_or_else(|| panic!("no mesh link to worker {owner}"));
        // Tag this rank thread's tap records with the destination link
        // (only when the tap is armed; the thread-local write is not
        // free enough for the default hot path).
        if wiretap::enabled() {
            wiretap::set_link(owner as u32);
        }
        // A dead link mid-run means the peer process crashed; the
        // send contract has no error path (MPI_Send aborts too), so
        // panic this rank thread — the driver reports it as a failed
        // rank rather than hanging the whole workflow on a recv that
        // can never complete. The MAX_FRAME bound is checked before
        // any byte goes out, so an oversized body fails just this send
        // without desyncing the link.
        //
        // Shm fast path: both workers sit on one host (all mesh links
        // do today, per `up`), so a large payload goes into a pooled
        // shm segment — one memcpy — and the socket carries only a
        // ~100-byte descriptor instead of two kernel copies per
        // payload byte. Chunking never engages here: the segment holds
        // the whole payload, however large. Any failure to lease a
        // segment degrades to the inline path below.
        if shm::enabled() && payload.len() >= shm::shm_min() {
            match self.shm_pool.acquire(payload.len()) {
                Some(slot) => {
                    slot.write(&payload);
                    let desc = proto::ShmDesc {
                        dst_global: dst_global as u64,
                        src_global: src_global as u64,
                        comm_id,
                        tag,
                        seg_id: slot.seg_id,
                        len: payload.len() as u64,
                        cap: slot.cap as u64,
                        name: slot.name.clone(),
                    };
                    let body = desc.encode();
                    // The codec's tap skips shm descriptors; record
                    // the descriptor *with* the segment image here so
                    // a full trace can replay the delivery even though
                    // the payload bytes never crossed the socket.
                    wiretap::frame_with_image(
                        wiretap::Dir::Tx,
                        proto::K_DATA_SHM,
                        &[&body],
                        &payload,
                    );
                    if let Err(e) = w.send_parts(proto::K_DATA_SHM, &[&body]) {
                        panic!("mesh link to worker {owner} failed: {e}");
                    }
                    Ctr::BytesShm.bump(payload.len() as u64);
                    return;
                }
                None => Ctr::ShmFallbacks.bump(1),
            }
        }
        if payload.len() <= codec::chunk_size() {
            let res = if buf::pooling_enabled() {
                // Pooled plane: stack-built envelope head, payload
                // bytes gathered straight off the caller's buffer
                // (tiny envelopes stage for coalescing instead).
                let head = proto::encode_data_header(
                    dst_global as u64,
                    src_global as u64,
                    comm_id,
                    tag,
                    payload.len(),
                );
                w.send_parts(proto::K_DATA, &[head.as_slice(), payload.as_slice()])
            } else {
                // Ablation arm: the historical concatenating encode.
                let body = proto::encode_data(
                    dst_global as u64,
                    src_global as u64,
                    comm_id,
                    tag,
                    &payload,
                );
                w.send(proto::K_DATA, &body)
            };
            if let Err(e) = res {
                panic!("mesh link to worker {owner} failed: {e}");
            }
            return;
        }
        // Large payload: stream bounded chunks. Each chunk takes and
        // releases the writer's lock, so concurrent senders interleave
        // at chunk granularity; the receiving side reassembles by
        // (sender, seq).
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        if buf::pooling_enabled() {
            // Pooled plane: each chunk is an O(1) slice of the payload,
            // written vectored after its stack-built header — the
            // payload bytes are never copied on the send side.
            for c in proto::chunk_payload(
                dst_global as u64,
                src_global as u64,
                comm_id,
                tag,
                seq,
                &payload,
                codec::chunk_size(),
            ) {
                let head = proto::encode_data_chunk_header(&c);
                if let Err(e) =
                    w.send_parts(proto::K_DATA_CHUNK, &[head.as_slice(), c.bytes.as_slice()])
                {
                    panic!("mesh link to worker {owner} failed: {e}");
                }
            }
            return;
        }
        // Ablation arm: owned chunk splits + concatenating encodes,
        // the pre-pooled data plane bit for bit.
        for c in proto::chunk_payload_owned(
            dst_global as u64,
            src_global as u64,
            comm_id,
            tag,
            seq,
            &payload,
            codec::chunk_size(),
        ) {
            let body = proto::encode_data_chunk(&c);
            if let Err(e) = w.send(proto::K_DATA_CHUNK, &body) {
                panic!("mesh link to worker {owner} failed: {e}");
            }
        }
    }

    fn is_local(&self, dst_global: usize) -> bool {
        self.hosts(dst_global)
    }

    fn shutdown(&self) {
        for w in self.peers.iter().flatten() {
            w.shutdown_link();
        }
    }

    /// A rank is about to block waiting for inbound data: push any
    /// staged tiny frames (credit grants, `Done`s) to the kernel *now*
    /// instead of waiting for the I/O thread's loop boundary — the
    /// peer may be blocked on exactly those frames.
    fn flush_hint(&self) {
        for w in self.peers.iter().flatten() {
            let _ = w.flush_blocking();
        }
    }
}

/// Decode a data envelope per the process's pooling mode: zero-copy
/// payload slice when pooled, historical copy-out otherwise.
pub(crate) fn decode_data_any(body: &Payload) -> Result<proto::DataMsg> {
    if buf::pooling_enabled() {
        proto::decode_data_payload(body)
    } else {
        proto::decode_data(body)
    }
}

/// Decode a chunk envelope per the process's pooling mode.
pub(crate) fn decode_chunk_any(body: &Payload) -> Result<proto::DataChunk> {
    if buf::pooling_enabled() {
        proto::decode_data_chunk_payload(body)
    } else {
        proto::decode_data_chunk(body)
    }
}

/// Connect + handshake helper shared by mesh building and rendezvous:
/// TCP with Nagle off (the substrate moves many small protocol
/// messages whose latency is the whole point).
pub(crate) fn connect(addr: &str) -> Result<TcpStream> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| WilkinsError::Comm(format!("connect {addr}: {e}")))?;
    stream
        .set_nodelay(true)
        .map_err(|e| WilkinsError::Comm(format!("set_nodelay: {e}")))?;
    Ok(stream)
}
