//! The transport I/O thread: one event loop per process owning the
//! receive side of every mesh and control link, plus the staging
//! writers that coalesce small frames on the send side.
//!
//! Receive: every link registers its read half (nonblocking) with the
//! [`poller`](super::poller); the loop decodes frames incrementally
//! ([`codec::NbFrameReader`]) and feeds them to the link's [`Sink`] —
//! mailbox pushes for mesh links, an mpsc channel for a worker's
//! control link. The old design burned one parked pump thread per
//! duplex link (O(workers²) per process) plus a beat thread per
//! surface; all of that folds into this single thread and its timer
//! wheel.
//!
//! Send: rank threads write through a per-link [`FrameWriter`]. Small
//! frames (flow `Done`/credit grants, heartbeats, telemetry, small
//! data envelopes) are *staged* — appended to a per-link buffer and
//! flushed as one write at the next poll-loop boundary (or inline at a
//! size threshold), so N tiny frames cost one syscall instead of N.
//! Large frames flush the stage and go down directly (vectored, no
//! payload copy), preserving FIFO order per link. The
//! `frames_coalesced` counter reports exactly the syscalls avoided.
//!
//! Locking discipline (deadlock-critical): the I/O thread never takes
//! a blocking lock and never blocks on a socket write — it uses
//! `try_lock`/`try_flush` and retries via a timer. Rank threads may
//! block (their writes go through [`BlockingIo`], which waits for
//! `POLLOUT` on `WouldBlock` — the shared file description is
//! nonblocking once the read half registers with the poller).

use std::collections::HashMap;
use std::io::{self, IoSlice, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::comm::buf::Payload;
use crate::comm::{Envelope, Mailboxes};
use crate::error::{Result, WilkinsError};
use crate::obs::{global_snapshot, wiretap, Clock, Ctr, TelemetrySample};

use super::codec::{self, NbFrameReader, NbRead};
use super::faults::FaultPlan;
use super::poller::{Event, Interest, Poller, Timers, Token, Waker};
use super::proto;
use super::shm::{self, ShmDelivery, ShmMap, ShmPool};
use super::transport::{decode_chunk_any, decode_data_any, SocketTransport};

/// Frames with a body at or under this size are staged for coalescing
/// instead of written immediately. Covers every control-plane tiny
/// frame (heartbeats ~16 B, telemetry ~80 B, flow Done/credit
/// envelopes well under 200 B) while keeping real data slabs on the
/// direct vectored path.
pub(crate) const COALESCE_MAX: usize = 512;

/// A staging buffer past this size flushes inline from the staging
/// thread instead of waiting for the I/O thread — bounds staged bytes
/// without a syscall per tiny frame.
const FLUSH_HIGH: usize = 16 * 1024;

/// Capacity a drained staging buffer is trimmed back to.
const STAGED_RECLAIM: usize = 64 * 1024;

/// Retry cadence when a loop-boundary flush could not finish (staging
/// lock contended or the kernel buffer full).
const FLUSH_RETRY: Duration = Duration::from_micros(500);

/// Max frames decoded per link per readiness event before yielding to
/// other links (fairness; level-triggered polling re-reports the fd).
const FRAMES_PER_EVENT: usize = 64;

/// Poller token reserved for the waker pipe.
const WAKE_TOKEN: u64 = u64::MAX;

#[cfg(unix)]
fn raw_fd(s: &TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd(_s: &TcpStream) -> i32 {
    -1
}

/// Blocking-write adapter over a stream whose shared file description
/// went nonblocking when its read half registered with the poller:
/// retries `WouldBlock` by parking in `poll(POLLOUT)`, so rank threads
/// keep the blocking-send semantics they always had.
pub(crate) struct BlockingIo<'a>(pub(crate) &'a TcpStream);

impl BlockingIo<'_> {
    #[cfg(unix)]
    fn wait_writable(&self) -> io::Result<()> {
        super::poller::wait_fd(raw_fd(self.0), true, None)?;
        Ok(())
    }

    #[cfg(not(unix))]
    fn wait_writable(&self) -> io::Result<()> {
        // Non-unix never reaches here: the poller cannot be built, so
        // no stream ever goes nonblocking.
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "wilkins net: nonblocking write retry is unix-only",
        ))
    }
}

impl Write for BlockingIo<'_> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        loop {
            match (&mut &*self.0).write(buf) {
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => self.wait_writable()?,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                other => return other,
            }
        }
    }

    fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> io::Result<usize> {
        loop {
            match (&mut &*self.0).write_vectored(bufs) {
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => self.wait_writable()?,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                other => return other,
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        (&mut &*self.0).flush()
    }
}

/// The guarded state of one [`FrameWriter`]: the socket write half and
/// the staged (encoded-but-unsent) small frames.
struct WriterInner {
    stream: TcpStream,
    staged: Vec<u8>,
}

/// Per-link staging writer: the send half of a mesh or control link.
///
/// All writes to a link go through its one `FrameWriter`, so frame
/// order on the wire is exactly staging/send order and frames can
/// never interleave mid-frame. Small frames stage; large frames flush
/// the stage and write directly.
pub(crate) struct FrameWriter {
    inner: Mutex<WriterInner>,
    /// True while staged bytes await a flush. Transitions happen under
    /// the `inner` lock; readers use it as a cheap skip-check.
    dirty: AtomicBool,
    /// The I/O thread to nudge when staging makes the writer dirty.
    io: Weak<IoShared>,
}

impl FrameWriter {
    pub(crate) fn new(stream: TcpStream, io: Weak<IoShared>) -> Arc<FrameWriter> {
        Arc::new(FrameWriter {
            inner: Mutex::new(WriterInner { stream, staged: Vec::new() }),
            dirty: AtomicBool::new(false),
            io,
        })
    }

    /// Send one frame with a contiguous body (stages it when small).
    pub(crate) fn send(&self, kind: u8, body: &[u8]) -> Result<()> {
        self.send_parts(kind, &[body])
    }

    /// Send one frame with a scattered body. Bodies totalling at most
    /// [`COALESCE_MAX`] are staged for a coalesced flush; larger ones
    /// flush the stage (FIFO order) and go to the kernel directly as
    /// one vectored write.
    pub(crate) fn send_parts(&self, kind: u8, parts: &[&[u8]]) -> Result<()> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let mut inner = self.inner.lock().unwrap();
        if total <= COALESCE_MAX {
            stage_into(&mut inner, kind, parts);
            if inner.staged.len() >= FLUSH_HIGH {
                return self.flush_locked(&mut inner);
            }
            let was_dirty = self.dirty.swap(true, Ordering::AcqRel);
            drop(inner);
            if !was_dirty {
                if let Some(shared) = self.io.upgrade() {
                    shared.waker.wake();
                }
            }
            return Ok(());
        }
        self.flush_locked(&mut inner)?;
        codec::write_frame_vectored(&mut BlockingIo(&inner.stream), kind, parts)
    }

    /// Stage one small frame from the I/O thread itself. Uses
    /// `try_lock` — the I/O thread must never block on a rank thread
    /// mid-write — and returns whether the frame was staged. A skipped
    /// beat is fine: a contended lock means the rank side is actively
    /// writing, which is itself proof of life on the link.
    pub(crate) fn try_stage(&self, kind: u8, body: &[u8]) -> bool {
        debug_assert!(body.len() <= COALESCE_MAX);
        let Ok(mut inner) = self.inner.try_lock() else {
            return false;
        };
        stage_into(&mut inner, kind, &[body]);
        // No wake: the I/O thread flushes at its own loop boundary.
        self.dirty.store(true, Ordering::Release);
        true
    }

    /// Flush staged frames from a rank thread (blocking write).
    pub(crate) fn flush_blocking(&self) -> Result<()> {
        if !self.dirty.load(Ordering::Acquire) {
            return Ok(());
        }
        let mut inner = self.inner.lock().unwrap();
        self.flush_locked(&mut inner)
    }

    /// Nonblocking flush attempt from the I/O thread. Returns `true`
    /// when nothing remains staged (flushed, empty, or the link is
    /// broken — broken links drop their stage; the read side owns the
    /// diagnosis). Returns `false` when bytes remain (lock contended
    /// or the kernel buffer is full) — retry at the next boundary.
    pub(crate) fn try_flush(&self) -> bool {
        if !self.dirty.load(Ordering::Acquire) {
            return true;
        }
        let Ok(mut inner) = self.inner.try_lock() else {
            return false;
        };
        let WriterInner { stream, staged } = &mut *inner;
        let mut off = 0usize;
        while off < staged.len() {
            match (&mut &*stream).write(&staged[off..]) {
                Ok(0) => break, // dead link: fall through to the clear
                Ok(n) => off += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    staged.drain(..off);
                    return false;
                }
                Err(_) => break, // dead link: fall through to the clear
            }
        }
        staged.clear();
        if staged.capacity() > STAGED_RECLAIM {
            staged.shrink_to(STAGED_RECLAIM);
        }
        self.dirty.store(false, Ordering::Release);
        true
    }

    /// Drain the stage with a blocking write (caller holds the lock).
    /// On error the stage is dropped — a broken link cannot be retried
    /// — and the error propagates to the sender.
    fn flush_locked(&self, inner: &mut WriterInner) -> Result<()> {
        let WriterInner { stream, staged } = inner;
        let res = if staged.is_empty() {
            Ok(())
        } else {
            BlockingIo(stream).write_all(staged).map_err(WilkinsError::Io)
        };
        staged.clear();
        if staged.capacity() > STAGED_RECLAIM {
            staged.shrink_to(STAGED_RECLAIM);
        }
        self.dirty.store(false, Ordering::Release);
        res
    }

    /// Orderly link teardown: flush, send a `Shutdown` frame, close
    /// our write direction. Errors are ignored — the peer may already
    /// be gone, which is exactly what shutdown is for.
    pub(crate) fn shutdown_link(&self) {
        let mut inner = self.inner.lock().unwrap();
        let _ = self.flush_locked(&mut inner);
        let _ = codec::write_frame(&mut BlockingIo(&inner.stream), proto::K_SHUTDOWN, &[]);
        let _ = inner.stream.shutdown(Shutdown::Write);
    }

    /// Abrupt teardown (kill emulation): close both directions with no
    /// goodbye frame, exactly like a process dying.
    pub(crate) fn shutdown_both(&self) {
        let inner = self.inner.lock().unwrap();
        let _ = inner.stream.shutdown(Shutdown::Both);
    }
}

/// Append one encoded frame to the stage (caller holds the lock) and
/// note it for observability. Counting coalescing at *stage* time —
/// one bump per frame that joins an already-nonempty stage — makes the
/// counter exact regardless of how flushes later split the buffer:
/// each bump is one `write` syscall that the old one-write-per-frame
/// path would have made and this path provably will not.
fn stage_into(inner: &mut WriterInner, kind: u8, parts: &[&[u8]]) {
    let body_len: usize = parts.iter().map(|p| p.len()).sum();
    if !inner.staged.is_empty() {
        Ctr::FramesCoalesced.bump(1);
    }
    inner.staged.extend_from_slice(&(body_len as u32).to_le_bytes());
    inner.staged.push(kind);
    for p in parts {
        inner.staged.extend_from_slice(p);
    }
    codec::note_tx(kind, parts);
}

/// Where a link's decoded inbound frames go.
pub(crate) enum Sink {
    /// A worker⇄worker mesh link: data envelopes land in the shared
    /// mailboxes (reassembling chunked ones), exactly as the old
    /// per-link pump thread delivered them. Shm descriptors resolve
    /// through `shm_maps` (one mapping per segment, cached for the
    /// link's lifetime — segments never retire mid-run, so the cache
    /// is bounded by the producer pool's segment cap) and their acks
    /// ride back on `writer`; inbound `K_SHM_ACK`s credit `shm_pool`.
    Mesh {
        mailboxes: Arc<Mailboxes>,
        peer_id: usize,
        assembler: proto::ChunkAssembler,
        /// Write half of this link (consumer→producer ack channel).
        writer: Arc<FrameWriter>,
        /// This process's producer-side pool (ack target).
        shm_pool: Arc<ShmPool>,
        /// Consumer-side mapping cache, keyed by segment name.
        shm_maps: HashMap<String, Arc<ShmMap>>,
    },
    /// A worker's control link: frames forward to the serve loop.
    Control { events: mpsc::Sender<ControlEvent> },
}

/// One observation forwarded from the I/O thread to a control-link
/// serve loop.
pub(crate) enum ControlEvent {
    /// A complete inbound frame (kind, body).
    Frame((u8, Payload)),
    /// The link closed: `None` for a clean EOF at a frame boundary,
    /// `Some(diagnosis)` for a stream error.
    Closed(Option<String>),
}

/// The periodic control-socket beat a worker arms on its I/O thread:
/// heartbeat + piggybacked telemetry snapshot every `interval`, until
/// a fired fault silences the worker.
pub(crate) struct ControlBeat {
    pub(crate) writer: Arc<FrameWriter>,
    pub(crate) worker_id: u64,
    pub(crate) interval: Duration,
    pub(crate) faults: Arc<FaultPlan>,
    pub(crate) clock: Clock,
}

/// Commands delivered to the I/O thread through the waker pipe.
enum Cmd {
    AddLink {
        token: u64,
        stream: TcpStream,
        sink: Sink,
        tap_link: u32,
        liveness: Option<(Duration, Duration)>,
        writer: Option<Arc<FrameWriter>>,
    },
    MeshBeat {
        transport: Weak<SocketTransport>,
        interval: Duration,
    },
    ControlBeat(ControlBeat),
}

/// State shared between the I/O thread and every handle that feeds it.
pub(crate) struct IoShared {
    cmds: Mutex<Vec<Cmd>>,
    waker: Waker,
    stop: AtomicBool,
    next_token: AtomicU64,
}

/// Joins the I/O thread when the last [`IoRt`] handle drops: stop flag
/// + wake + join, so shutdown is deterministic and leak-free (the old
/// pump threads were detached and simply abandoned).
struct JoinGuard {
    shared: Arc<IoShared>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for JoinGuard {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.waker.wake();
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Handle to the process's transport I/O thread. Clone freely; the
/// thread is stopped and joined when the last clone drops.
#[derive(Clone)]
pub(crate) struct IoRt {
    shared: Arc<IoShared>,
    guard: Arc<JoinGuard>,
    finished: Arc<AtomicBool>,
}

impl IoRt {
    /// Spawn the I/O thread (poller + waker built up front, so an
    /// unsupported platform fails here, loudly, not mid-run).
    pub(crate) fn spawn() -> Result<IoRt> {
        let map = |e: io::Error| WilkinsError::Comm(format!("spawn transport io thread: {e}"));
        let poller = Poller::new().map_err(map)?;
        let waker = Waker::new().map_err(map)?;
        poller
            .register(waker.read_fd(), Token(WAKE_TOKEN), Interest::READABLE)
            .map_err(map)?;
        let shared = Arc::new(IoShared {
            cmds: Mutex::new(Vec::new()),
            waker,
            stop: AtomicBool::new(false),
            next_token: AtomicU64::new(0),
        });
        let finished = Arc::new(AtomicBool::new(false));
        let (shared2, finished2) = (Arc::clone(&shared), Arc::clone(&finished));
        let handle = std::thread::Builder::new()
            .name("wk-io".into())
            .spawn(move || io_main(poller, shared2, finished2))
            .map_err(map)?;
        let guard = Arc::new(JoinGuard {
            shared: Arc::clone(&shared),
            handle: Mutex::new(Some(handle)),
        });
        Ok(IoRt { shared, guard, finished })
    }

    /// Hand one link's read half to the I/O thread. The stream goes
    /// nonblocking on registration — which flips the *shared file
    /// description*, so the paired write half must route every write
    /// through [`FrameWriter`]/[`BlockingIo`] from that point on.
    pub(crate) fn add_link(
        &self,
        stream: TcpStream,
        sink: Sink,
        tap_link: u32,
        liveness: Option<(Duration, Duration)>,
        writer: Option<Arc<FrameWriter>>,
    ) {
        let token = self.shared.next_token.fetch_add(1, Ordering::Relaxed);
        self.push_cmd(Cmd::AddLink { token, stream, sink, tap_link, liveness, writer });
    }

    /// Arm the periodic mesh beat: one staged heartbeat per link per
    /// `interval`, stopping when the transport (the world) drops.
    pub(crate) fn add_mesh_beat(&self, transport: Weak<SocketTransport>, interval: Duration) {
        self.push_cmd(Cmd::MeshBeat { transport, interval });
    }

    /// Arm a worker's control-socket beat (heartbeat + telemetry).
    pub(crate) fn add_control_beat(&self, beat: ControlBeat) {
        self.push_cmd(Cmd::ControlBeat(beat));
    }

    /// A weak handle for [`FrameWriter`]s to nudge the loop with.
    pub(crate) fn downgrade(&self) -> Weak<IoShared> {
        Arc::downgrade(&self.shared)
    }

    /// Flag the I/O thread sets on its way out — lets tests assert
    /// the thread really exited (no leak) after the last handle drops.
    #[cfg(test)]
    pub(crate) fn finished_probe(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.finished)
    }

    fn push_cmd(&self, cmd: Cmd) {
        self.shared.cmds.lock().unwrap().push(cmd);
        self.shared.waker.wake();
    }
}

/// One registered link inside the loop.
struct LinkState {
    stream: TcpStream,
    reader: NbFrameReader,
    sink: Sink,
    tap_link: u32,
    last_rx: Instant,
    /// Silence past this kills the link (mesh liveness).
    deadline: Option<Duration>,
}

/// Deferred per-interval work, folded into the single timer wheel.
enum TimerKind {
    /// A loop-boundary flush could not finish; make sure the loop
    /// wakes soon to retry (the flush pass itself does the work).
    FlushRetry,
    /// Mesh heartbeat tick.
    MeshBeat {
        transport: Weak<SocketTransport>,
        interval: Duration,
        seq: u64,
    },
    /// Control heartbeat + telemetry tick.
    ControlBeat { beat: ControlBeat, seq: u64 },
    /// Liveness check for one link.
    Liveness { token: u64, interval: Duration },
}

thread_local! {
    /// True on the `wk-io` thread (set once at `io_main` entry).
    /// `ShmDelivery::Drop` consults it: the last payload view of a shm
    /// delivery usually drops on a rank thread, but a sink torn down
    /// with unread envelopes drops its views on the I/O thread itself,
    /// where the reclamation ack must take the never-blocking
    /// `try_stage` path instead of `send_parts`.
    static ON_IO_THREAD: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Is the calling thread the process's transport I/O thread?
pub(crate) fn on_io_thread() -> bool {
    ON_IO_THREAD.with(|f| f.get())
}

/// The event loop. Runs until the stop flag is raised (last handle
/// dropped) or the poller itself fails.
fn io_main(poller: Poller, shared: Arc<IoShared>, finished: Arc<AtomicBool>) {
    ON_IO_THREAD.with(|f| f.set(true));
    let mut links: HashMap<u64, LinkState> = HashMap::new();
    let mut writers: Vec<Arc<FrameWriter>> = Vec::new();
    let mut timers: Timers<TimerKind> = Timers::new();
    let mut events: Vec<Event> = Vec::new();
    let mut flush_retry_armed = false;

    loop {
        // (1) Loop-boundary flush pass: drain every dirty stage. This
        // is where coalesced small frames actually hit the kernel —
        // at most one write per link per loop turn.
        let mut unfinished = false;
        for w in &writers {
            if !w.try_flush() {
                unfinished = true;
            }
        }
        if unfinished && !flush_retry_armed {
            timers.arm(Instant::now() + FLUSH_RETRY, TimerKind::FlushRetry);
            flush_retry_armed = true;
        }

        // (2) Wait for readiness or the next timer deadline.
        let timeout = timers
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()));
        events.clear();
        if let Err(e) = poller.wait(&mut events, timeout) {
            eprintln!("wilkins net: transport io poller failed: {e}");
            break;
        }
        Ctr::PollerWakeups.bump(1);

        // (3) Wake pipe, stop flag, pending commands.
        if events.iter().any(|ev| ev.token.0 == WAKE_TOKEN) {
            shared.waker.drain();
        }
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let cmds: Vec<Cmd> = std::mem::take(&mut *shared.cmds.lock().unwrap());
        for cmd in cmds {
            match cmd {
                Cmd::AddLink { token, stream, sink, tap_link, liveness, writer } => {
                    if let Err(e) = stream
                        .set_nonblocking(true)
                        .and_then(|()| poller.register(raw_fd(&stream), Token(token), Interest::READABLE))
                    {
                        eprintln!("wilkins net: cannot register link with poller: {e}");
                        continue;
                    }
                    if let Some(w) = writer {
                        writers.push(w);
                    }
                    let deadline = liveness.map(|(_, d)| d);
                    if let Some((interval, _)) = liveness {
                        timers.arm(
                            Instant::now() + interval,
                            TimerKind::Liveness { token, interval },
                        );
                    }
                    links.insert(
                        token,
                        LinkState {
                            stream,
                            reader: NbFrameReader::new(),
                            sink,
                            tap_link,
                            last_rx: Instant::now(),
                            deadline,
                        },
                    );
                }
                Cmd::MeshBeat { transport, interval } => {
                    timers.arm(
                        Instant::now() + interval,
                        TimerKind::MeshBeat { transport, interval, seq: 1 },
                    );
                }
                Cmd::ControlBeat(beat) => {
                    let interval = beat.interval;
                    timers.arm(
                        Instant::now() + interval,
                        TimerKind::ControlBeat { beat, seq: 1 },
                    );
                }
            }
        }

        // (4) Service readable links.
        for ev in &events {
            if ev.token.0 != WAKE_TOKEN {
                service_link(&poller, &mut links, ev.token.0);
            }
        }

        // (5) Fire due timers.
        for kind in timers.pop_expired(Instant::now()) {
            match kind {
                TimerKind::FlushRetry => {
                    // The flush pass at the top of the loop retries;
                    // this timer only bounded the sleep.
                    flush_retry_armed = false;
                }
                TimerKind::MeshBeat { transport, interval, seq } => {
                    if let Some(t) = transport.upgrade() {
                        t.beat_all_staged(seq);
                        timers.arm(
                            Instant::now() + interval,
                            TimerKind::MeshBeat { transport, interval, seq: seq + 1 },
                        );
                    }
                }
                TimerKind::ControlBeat { beat, seq } => {
                    if beat.faults.silenced() {
                        continue; // silenced workers never beat again
                    }
                    // Snapshot *before* staging the beat, so the
                    // cumulative snapshot excludes this very frame
                    // (the next one picks it up) — the historical
                    // beat-thread ordering.
                    let hb = proto::Heartbeat { worker_id: beat.worker_id, seq };
                    let telem = TelemetrySample {
                        worker_id: beat.worker_id,
                        seq,
                        t_mono_s: beat.clock.now_s(),
                        counters: global_snapshot(),
                    };
                    wiretap::set_link(wiretap::LINK_UNSET);
                    if beat.writer.try_stage(proto::K_HEARTBEAT, &hb.encode()) {
                        Ctr::HeartbeatsSent.bump(1);
                        if beat.writer.try_stage(proto::K_TELEMETRY, &telem.encode()) {
                            Ctr::TelemetrySent.bump(1);
                        }
                    }
                    let interval = beat.interval;
                    timers.arm(
                        Instant::now() + interval,
                        TimerKind::ControlBeat { beat, seq: seq + 1 },
                    );
                }
                TimerKind::Liveness { token, interval } => {
                    let Some(link) = links.get(&token) else {
                        continue; // link already closed; timer lapses
                    };
                    let deadline = link.deadline.unwrap_or(Duration::MAX);
                    if link.last_rx.elapsed() >= deadline {
                        if let Sink::Mesh { peer_id, .. } = link.sink {
                            eprintln!(
                                "wilkins net: mesh link from worker {peer_id} died \
                                 (silent past the {:.1}s heartbeat deadline); \
                                 ranks waiting on it will time out",
                                deadline.as_secs_f64()
                            );
                        }
                        close_link(&poller, &mut links, token);
                        continue;
                    }
                    timers.arm(
                        Instant::now() + interval,
                        TimerKind::Liveness { token, interval },
                    );
                }
            }
        }
    }

    // Final drain: anything still staged (replies, shutdown-adjacent
    // beats) goes out with blocking writes. Tiny frames always fit the
    // kernel buffer, so this cannot hang on a live peer; dead links
    // error and drop their stage silently.
    for w in &writers {
        let _ = w.flush_blocking();
    }
    finished.store(true, Ordering::SeqCst);
}

/// Why a link is being closed quietly (diagnostics already printed or
/// deliberately suppressed).
fn close_link(poller: &Poller, links: &mut HashMap<u64, LinkState>, token: u64) {
    if let Some(link) = links.remove(&token) {
        let _ = poller.deregister(raw_fd(&link.stream));
        if let Sink::Control { events } = &link.sink {
            // A serve loop that already exited makes this send fail;
            // that is fine — nobody is left to care.
            let _ = events.send(ControlEvent::Closed(None));
        }
    }
}

/// Resolve one inbound `K_DATA_SHM` descriptor into a deliverable
/// message: map (or re-use the cached mapping of) the named segment
/// and wrap it as a [`Payload`] region whose last-view drop stages the
/// `K_SHM_ACK` on `writer`. Also taps the delivery (descriptor +
/// segment image) — the codec's reader deliberately skipped it so the
/// trace carries the payload bytes the socket never did.
fn shm_frame_to_msg(
    body: &Payload,
    writer: &Arc<FrameWriter>,
    shm_maps: &mut HashMap<String, Arc<ShmMap>>,
) -> Result<proto::DataMsg> {
    let desc = proto::ShmDesc::decode(body)?;
    let map = match shm_maps.get(&desc.name) {
        Some(m) => Arc::clone(m),
        None => {
            let m = shm::open_map(&desc.name, desc.cap as usize)?;
            shm_maps.insert(desc.name.clone(), Arc::clone(&m));
            m
        }
    };
    let len = desc.len as usize;
    wiretap::frame_with_image(
        wiretap::Dir::Rx,
        proto::K_DATA_SHM,
        &[body.as_slice()],
        &map.as_slice()[..len],
    );
    let delivery = ShmDelivery { map, len, seg_id: desc.seg_id, writer: Arc::clone(writer) };
    Ok(proto::DataMsg {
        dst_global: desc.dst_global,
        src_global: desc.src_global,
        comm_id: desc.comm_id,
        tag: desc.tag,
        payload: Payload::from_region(Arc::new(delivery)),
    })
}

/// Drain one readable link: decode up to [`FRAMES_PER_EVENT`] frames
/// and dispatch them to the sink. The dispatch table reproduces the
/// old per-link pump thread's behavior — including its exact stderr
/// diagnostics — frame for frame.
fn service_link(poller: &Poller, links: &mut HashMap<u64, LinkState>, token: u64) {
    let Some(link) = links.get_mut(&token) else {
        return; // stale event for a link closed earlier this turn
    };
    // Every frame read here crossed this one link; stamp the tap.
    wiretap::set_link(link.tap_link);

    // `None` = keep the link; `Some(notify)` = close it, with
    // `notify` carrying a control-link error diagnosis (mesh links
    // print their diagnosis inline, matching the old pump).
    let mut close: Option<Option<String>> = None;
    'frames: for _ in 0..FRAMES_PER_EVENT {
        let LinkState { stream, reader, sink, last_rx, .. } = link;
        let mut rs: &TcpStream = &*stream;
        match reader.read_from(&mut rs) {
            Ok(NbRead::Frame((kind, payload))) => {
                *last_rx = Instant::now();
                match sink {
                    Sink::Mesh { mailboxes, peer_id, assembler, writer, shm_pool, shm_maps } => {
                        let peer_id = *peer_id;
                        match kind {
                            proto::K_DATA => match decode_data_any(&payload) {
                                Ok(msg) => mailboxes.push(
                                    msg.dst_global as usize,
                                    Envelope {
                                        src_global: msg.src_global as usize,
                                        comm_id: msg.comm_id,
                                        tag: msg.tag,
                                        payload: msg.payload,
                                    },
                                ),
                                Err(e) => {
                                    eprintln!(
                                        "wilkins net: mesh link from worker {peer_id} died \
                                         (bad data frame: {e}); ranks waiting on it will time out"
                                    );
                                    close = Some(None);
                                    break 'frames;
                                }
                            },
                            proto::K_DATA_CHUNK => {
                                let complete =
                                    decode_chunk_any(&payload).and_then(|c| assembler.feed(c));
                                match complete {
                                    Ok(Some(msg)) => mailboxes.push(
                                        msg.dst_global as usize,
                                        Envelope {
                                            src_global: msg.src_global as usize,
                                            comm_id: msg.comm_id,
                                            tag: msg.tag,
                                            payload: msg.payload,
                                        },
                                    ),
                                    Ok(None) => {} // mid-reassembly
                                    Err(e) => {
                                        eprintln!(
                                            "wilkins net: mesh link from worker {peer_id} died \
                                             (bad chunk: {e}); ranks waiting on it will time out"
                                        );
                                        close = Some(None);
                                        break 'frames;
                                    }
                                }
                            }
                            // Shm descriptor: the payload sits in a
                            // mapped segment; deliver a Payload view
                            // of the mapping (ack staged when its last
                            // view drops). A segment that cannot be
                            // resolved is as fatal as a bad data frame
                            // — the message is unrecoverable.
                            proto::K_DATA_SHM => {
                                match shm_frame_to_msg(&payload, writer, shm_maps) {
                                    Ok(msg) => mailboxes.push(
                                        msg.dst_global as usize,
                                        Envelope {
                                            src_global: msg.src_global as usize,
                                            comm_id: msg.comm_id,
                                            tag: msg.tag,
                                            payload: msg.payload,
                                        },
                                    ),
                                    Err(e) => {
                                        eprintln!(
                                            "wilkins net: mesh link from worker {peer_id} died \
                                             (bad shm frame: {e}); ranks waiting on it will time out"
                                        );
                                        close = Some(None);
                                        break 'frames;
                                    }
                                }
                            }
                            // Segment reclamation credit from a
                            // consumer of ours: the segment is free to
                            // rewrite.
                            proto::K_SHM_ACK => match proto::decode_shm_ack(&payload) {
                                Ok(seg_id) => shm_pool.ack(seg_id),
                                Err(e) => {
                                    eprintln!(
                                        "wilkins net: mesh link from worker {peer_id} died \
                                         (bad shm ack: {e}); ranks waiting on it will time out"
                                    );
                                    close = Some(None);
                                    break 'frames;
                                }
                            },
                            // Liveness beacon: `last_rx` already
                            // refreshed; never surfaces further.
                            proto::K_HEARTBEAT => {}
                            // Orderly teardown.
                            proto::K_SHUTDOWN => {
                                close = Some(None);
                                break 'frames;
                            }
                            kind => {
                                eprintln!(
                                    "wilkins net: mesh link from worker {peer_id} died \
                                     (unexpected frame kind {kind}); \
                                     ranks waiting on it will time out"
                                );
                                close = Some(None);
                                break 'frames;
                            }
                        }
                    }
                    Sink::Control { events } => {
                        if events.send(ControlEvent::Frame((kind, payload))).is_err() {
                            // Serve loop gone: nothing left to feed.
                            close = Some(None);
                            break 'frames;
                        }
                    }
                }
            }
            Ok(NbRead::WouldBlock) => break 'frames,
            Ok(NbRead::Eof) => {
                close = Some(None);
                break 'frames;
            }
            Err(e) => {
                match &link.sink {
                    Sink::Mesh { peer_id, .. } => eprintln!(
                        "wilkins net: mesh link from worker {peer_id} died ({e}); \
                         ranks waiting on it will time out"
                    ),
                    Sink::Control { .. } => {}
                }
                close = Some(Some(e.to_string()));
                break 'frames;
            }
        }
    }

    if let Some(err) = close {
        if let Some(link) = links.remove(&token) {
            let _ = poller.deregister(raw_fd(&link.stream));
            if let Sink::Control { events } = &link.sink {
                let _ = events.send(ControlEvent::Closed(err));
            }
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Satellite 2: the I/O thread is joined — not detached — when the
    /// last handle drops, so no thread can leak past shutdown.
    #[test]
    fn io_thread_joins_on_last_handle_drop() {
        let io = IoRt::spawn().unwrap();
        let probe = io.finished_probe();
        let clone = io.clone();
        drop(io);
        assert!(
            !probe.load(Ordering::SeqCst),
            "thread must stay alive while a handle remains"
        );
        drop(clone);
        // JoinGuard::drop joined the thread, so the flag is already set.
        assert!(
            probe.load(Ordering::SeqCst),
            "io thread must have exited (joined) after the last drop"
        );
    }

    /// Small frames staged back-to-back go to the kernel as ONE write,
    /// and the coalescing counter reports exactly the avoided writes.
    #[test]
    fn staged_small_frames_coalesce_into_one_flush() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (mut rx, _) = listener.accept().unwrap();

        // No I/O thread here (empty Weak): staging + explicit flush,
        // so the coalescing accounting is fully deterministic.
        let w = FrameWriter::new(tx, Weak::new());
        let before = Ctr::FramesCoalesced.get();
        w.send(proto::K_HEARTBEAT, b"beat-1").unwrap();
        w.send(proto::K_TELEMETRY, b"telemetry-2").unwrap();
        w.send(proto::K_HEARTBEAT, b"beat-3").unwrap();
        // Frames 2 and 3 joined a nonempty stage: 2 writes avoided.
        // (>= because unrelated tests may coalesce concurrently.)
        assert!(
            Ctr::FramesCoalesced.get() - before >= 2,
            "three staged frames must record two avoided writes"
        );
        w.flush_blocking().unwrap();

        // The peer reads all three frames, intact and in order.
        let f1 = codec::read_frame(&mut rx).unwrap().unwrap();
        let f2 = codec::read_frame(&mut rx).unwrap().unwrap();
        let f3 = codec::read_frame(&mut rx).unwrap().unwrap();
        assert_eq!(f1, (proto::K_HEARTBEAT, b"beat-1".to_vec()));
        assert_eq!(f2, (proto::K_TELEMETRY, b"telemetry-2".to_vec()));
        assert_eq!(f3, (proto::K_HEARTBEAT, b"beat-3".to_vec()));
    }

    /// A frame above COALESCE_MAX flushes the stage first and goes out
    /// directly — FIFO order holds across the two paths.
    #[test]
    fn large_frame_flushes_stage_and_preserves_order() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (mut rx, _) = listener.accept().unwrap();

        let w = FrameWriter::new(tx, Weak::new());
        let big = vec![7u8; COALESCE_MAX * 4];
        w.send(proto::K_HEARTBEAT, b"tiny-first").unwrap();
        w.send(proto::K_DATA, &big).unwrap(); // direct path, flushes stage
        let f1 = codec::read_frame(&mut rx).unwrap().unwrap();
        let f2 = codec::read_frame(&mut rx).unwrap().unwrap();
        assert_eq!(f1, (proto::K_HEARTBEAT, b"tiny-first".to_vec()));
        assert_eq!(f2.0, proto::K_DATA);
        assert_eq!(f2.1, big);
    }
}
