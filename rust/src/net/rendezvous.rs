//! Bootstrap and rendezvous for the multi-process substrate.
//!
//! The coordinator process binds a loopback listener, spawns workers
//! (`wilkins worker --connect <addr> --id <k>`), and collects one
//! `Hello` per worker carrying that worker's peer-mesh endpoint. The
//! resulting endpoint map plus a global-rank → worker assignment is
//! what `LaunchWorld` broadcasts; every worker then independently
//! builds the same mesh ([`build_mesh_world`]): connect to every
//! lower-id peer, accept from every higher-id peer, one duplex link
//! per unordered pair — every link's read half handed to the
//! process's single transport I/O thread (the crate-private
//! `net::io` module).
//!
//! Rank assignment itself lives here too ([`assign_nodes`]): whole
//! task instances (graph nodes) are dealt round-robin onto workers,
//! the `process-per-node` placement — a node's ranks share a process
//! (and its restricted-world traffic stays on mailboxes) while
//! channel traffic between nodes crosses sockets, which is exactly
//! the paper's node-per-task deployment shape.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::{Mailboxes, World};
use crate::error::{Result, WilkinsError};
use crate::graph::WorkflowGraph;

use super::codec;
use super::io::{FrameWriter, IoRt, Sink};
use super::proto::{self, Hello, LaunchWorld};
use super::shm::ShmPool;
use super::transport::{connect, SocketTransport};

/// How long rendezvous/mesh accepts wait for a counterpart to show
/// up. A worker or peer process that died before connecting must
/// surface as a readable error, not an infinite `accept()` hang.
const JOIN_TIMEOUT: Duration = Duration::from_secs(60);

/// How long a freshly accepted connection gets to complete its
/// `Hello`/`PeerHello`. A peer that connects and then wedges (or a
/// stray non-wilkins client) must fail the handshake loudly, not park
/// `wilkins up` startup in a blocking read forever.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Read one frame with [`HANDSHAKE_TIMEOUT`] armed, translating a
/// timeout into a named error. The stream's read timeout is cleared
/// again before returning.
fn read_handshake_frame(conn: &mut TcpStream, who: &str) -> Result<(u8, Vec<u8>)> {
    conn.set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .map_err(|e| WilkinsError::Comm(format!("set_read_timeout: {e}")))?;
    let got = codec::read_frame(conn);
    let _ = conn.set_read_timeout(None);
    match got {
        Ok(Some(frame)) => Ok(frame),
        Ok(None) => Err(WilkinsError::Comm(format!("{who} closed before handshake"))),
        Err(WilkinsError::Io(e)) if codec::is_timeout(&e) => Err(WilkinsError::Comm(format!(
            "{who} connected but sent no handshake within {}s (wedged peer?)",
            HANDSHAKE_TIMEOUT.as_secs()
        ))),
        Err(e) => Err(e),
    }
}

/// `accept()` with a deadline (nonblocking poll; the accepted stream
/// is switched back to blocking before use).
fn accept_deadline(listener: &TcpListener, who: &str) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .map_err(|e| WilkinsError::Comm(format!("set_nonblocking: {e}")))?;
    let deadline = Instant::now() + JOIN_TIMEOUT;
    let stream = loop {
        match listener.accept() {
            Ok((s, _)) => break s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    let _ = listener.set_nonblocking(false);
                    return Err(WilkinsError::Comm(format!(
                        "timed out after {}s waiting for {who} to connect \
                         (did a worker process die before rendezvous?)",
                        JOIN_TIMEOUT.as_secs()
                    )));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                let _ = listener.set_nonblocking(false);
                return Err(WilkinsError::Comm(format!("accept {who}: {e}")));
            }
        }
    };
    listener
        .set_nonblocking(false)
        .map_err(|e| WilkinsError::Comm(format!("set_nonblocking: {e}")))?;
    stream
        .set_nonblocking(false)
        .map_err(|e| WilkinsError::Comm(format!("set_nonblocking: {e}")))?;
    Ok(stream)
}

/// Coordinator-side listener for worker control connections.
pub struct Rendezvous {
    listener: TcpListener,
    addr: String,
}

/// One worker's control connection, post-handshake.
pub struct WorkerLink {
    pub id: usize,
    /// The worker's peer-mesh endpoint (from its `Hello`).
    pub peer_addr: String,
    pub conn: TcpStream,
}

impl WorkerLink {
    /// Send one framed control message (bounds-checked, single
    /// `write_all`).
    pub fn send(&mut self, kind: u8, body: &[u8]) -> Result<()> {
        codec::write_frame(&mut self.conn, kind, body)
    }

    /// Blocking read of the next control frame; EOF is an error here
    /// (a worker must not vanish while the coordinator waits on it).
    pub fn recv(&mut self) -> Result<(u8, Vec<u8>)> {
        codec::read_frame(&mut self.conn)?.ok_or_else(|| {
            WilkinsError::Comm(format!("worker {} closed its control connection", self.id))
        })
    }
}

impl Rendezvous {
    /// Bind on an ephemeral loopback port.
    pub fn bind() -> Result<Rendezvous> {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| WilkinsError::Comm(format!("bind rendezvous listener: {e}")))?;
        let addr = listener
            .local_addr()
            .map_err(|e| WilkinsError::Comm(format!("rendezvous local_addr: {e}")))?
            .to_string();
        Ok(Rendezvous { listener, addr })
    }

    /// The address workers connect back to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Accept `n` workers and validate their handshakes. Returned
    /// links are ordered by worker id; duplicate or out-of-range ids
    /// fail the whole rendezvous.
    pub fn accept_workers(&self, n: usize) -> Result<Vec<WorkerLink>> {
        let mut links: Vec<Option<WorkerLink>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let mut conn = accept_deadline(&self.listener, "a worker")?;
            conn.set_nodelay(true)
                .map_err(|e| WilkinsError::Comm(format!("set_nodelay: {e}")))?;
            let (kind, body) = read_handshake_frame(&mut conn, "a worker")?;
            if kind != proto::K_HELLO {
                return Err(WilkinsError::Comm(format!(
                    "expected Hello frame, got kind {kind}"
                )));
            }
            let hello = Hello::decode(&body)?;
            let id = hello.worker_id as usize;
            if id >= n {
                return Err(WilkinsError::Comm(format!(
                    "worker id {id} out of range (pool of {n})"
                )));
            }
            if links[id].is_some() {
                return Err(WilkinsError::Comm(format!("duplicate worker id {id}")));
            }
            links[id] = Some(WorkerLink { id, peer_addr: hello.peer_addr, conn });
        }
        Ok(links.into_iter().map(|l| l.expect("all slots filled")).collect())
    }
}

/// Worker-side join: connect to the coordinator and introduce
/// ourselves (id + our peer-mesh endpoint).
pub fn join(coordinator_addr: &str, worker_id: usize, peer_addr: &str) -> Result<TcpStream> {
    let mut conn = connect(coordinator_addr)?;
    let hello = Hello { worker_id: worker_id as u64, peer_addr: peer_addr.to_string() };
    codec::write_frame(&mut conn, proto::K_HELLO, &hello.encode())?;
    Ok(conn)
}

/// Deal graph nodes (task instances) round-robin onto `nworkers`
/// processes; returns the owning worker id per global rank. Never
/// splits one node's ranks across processes.
pub fn assign_nodes(graph: &WorkflowGraph, nworkers: usize) -> Vec<u64> {
    let mut owner_of = vec![0u64; graph.total_ranks];
    for (ni, node) in graph.nodes.iter().enumerate() {
        let w = (ni % nworkers.max(1)) as u64;
        for r in node.ranks() {
            owner_of[r] = w;
        }
    }
    owner_of
}

/// Everything a worker holds while participating in a distributed
/// world: the world itself plus a handle on the I/O thread feeding
/// it. Keep it alive until the coordinator's final `Shutdown` — peers
/// may still be draining even after our own ranks finish.
///
/// Field order is the teardown order: `world` drops first (closing
/// the transport's write halves), then the `io` handle — when it is
/// the last handle on the I/O thread, the drop stops, wakes and
/// *joins* the thread, so mesh shutdown is deterministic and
/// leak-free. (The old per-link pump threads were detached and simply
/// abandoned at shutdown.)
pub struct MeshWorld {
    pub world: World,
    io: IoRt,
}

impl MeshWorld {
    /// Orderly teardown: flush + `Shutdown`-frame every peer and close
    /// our write halves. The I/O thread deregisters links as peers
    /// close their sides; it is joined when the last `IoRt` handle
    /// drops (here, for a standalone mesh world — the worker serve
    /// loop holds its own handle until the process winds down).
    pub fn shutdown(self) {
        self.world.shutdown_transport();
    }

    /// The I/O thread's exit flag, for thread-leak assertions.
    #[cfg(test)]
    pub(crate) fn io_finished_probe(&self) -> Arc<std::sync::atomic::AtomicBool> {
        self.io.finished_probe()
    }
}

/// Build this worker's side of the mesh + the socket-backed world,
/// spawning a dedicated I/O thread for it (tests, benches). Workers
/// already own an I/O thread for their control link and share it
/// (crate-private `build_mesh_world_on`).
pub fn build_mesh_world(
    my_id: usize,
    peer_listener: &TcpListener,
    msg: &LaunchWorld,
) -> Result<MeshWorld> {
    let io = IoRt::spawn()?;
    build_mesh_world_on(&io, my_id, peer_listener, msg)
}

/// Build the mesh on an existing I/O thread.
///
/// Deterministic pairing: for each unordered worker pair (i, j) with
/// i < j, worker j connects to worker i's peer listener and announces
/// itself with a `PeerHello`; worker i accepts. Either way both sides
/// end up with one duplex link per peer: the read half registered
/// (nonblocking) with the I/O thread, the write half wrapped in a
/// staging [`FrameWriter`] held by the [`SocketTransport`].
pub(crate) fn build_mesh_world_on(
    io: &IoRt,
    my_id: usize,
    peer_listener: &TcpListener,
    msg: &LaunchWorld,
) -> Result<MeshWorld> {
    let n = msg.endpoints.len();
    if my_id >= n {
        return Err(WilkinsError::Comm(format!(
            "worker id {my_id} out of range (endpoint map of {n})"
        )));
    }
    let total_ranks = msg.total_ranks as usize;
    let mailboxes = Arc::new(Mailboxes::new(total_ranks));
    let mut peers: Vec<Option<Arc<FrameWriter>>> = (0..n).map(|_| None).collect();
    // One shm segment pool per mesh world: shared by the transport
    // (which leases segments for large sends) and every mesh sink
    // (which credits them back as `K_SHM_ACK`s arrive). Pool drop —
    // world teardown — unlinks the segment files.
    let shm_pool = Arc::new(ShmPool::new());
    // Mesh liveness cadence from the coordinator (0 = disabled, the
    // pre-v5 blocking behavior).
    let liveness = if msg.heartbeat_ms > 0 {
        Some((
            Duration::from_millis(msg.heartbeat_ms),
            Duration::from_millis(msg.heartbeat_deadline_ms.max(msg.heartbeat_ms * 2)),
        ))
    } else {
        None
    };

    // Connect to every lower id.
    for (j, endpoint) in msg.endpoints.iter().enumerate().take(my_id) {
        let mut stream = connect(endpoint)?;
        codec::write_frame(
            &mut stream,
            proto::K_PEER_HELLO,
            &proto::encode_peer_hello(my_id as u64),
        )?;
        let read_half = stream
            .try_clone()
            .map_err(|e| WilkinsError::Comm(format!("clone mesh stream: {e}")))?;
        let writer = FrameWriter::new(stream, io.downgrade());
        io.add_link(
            read_half,
            Sink::Mesh {
                mailboxes: Arc::clone(&mailboxes),
                peer_id: j,
                assembler: proto::ChunkAssembler::new(),
                writer: Arc::clone(&writer),
                shm_pool: Arc::clone(&shm_pool),
                shm_maps: HashMap::new(),
            },
            j as u32,
            liveness,
            Some(Arc::clone(&writer)),
        );
        peers[j] = Some(writer);
    }

    // Accept from every higher id (they arrive in any order).
    for _ in my_id + 1..n {
        let mut stream = accept_deadline(peer_listener, "a mesh peer")?;
        stream
            .set_nodelay(true)
            .map_err(|e| WilkinsError::Comm(format!("set_nodelay: {e}")))?;
        let (kind, body) = read_handshake_frame(&mut stream, "a mesh peer")?;
        if kind != proto::K_PEER_HELLO {
            return Err(WilkinsError::Comm(format!(
                "expected PeerHello on mesh link, got kind {kind}"
            )));
        }
        let peer = proto::decode_peer_hello(&body)? as usize;
        if peer <= my_id || peer >= n {
            return Err(WilkinsError::Comm(format!(
                "unexpected mesh peer id {peer} (we are {my_id} of {n})"
            )));
        }
        if peers[peer].is_some() {
            return Err(WilkinsError::Comm(format!("duplicate mesh link from {peer}")));
        }
        let read_half = stream
            .try_clone()
            .map_err(|e| WilkinsError::Comm(format!("clone mesh stream: {e}")))?;
        let writer = FrameWriter::new(stream, io.downgrade());
        io.add_link(
            read_half,
            Sink::Mesh {
                mailboxes: Arc::clone(&mailboxes),
                peer_id: peer,
                assembler: proto::ChunkAssembler::new(),
                writer: Arc::clone(&writer),
                shm_pool: Arc::clone(&shm_pool),
                shm_maps: HashMap::new(),
            },
            peer as u32,
            liveness,
            Some(Arc::clone(&writer)),
        );
        peers[peer] = Some(writer);
    }

    let owner_of: Vec<usize> = msg.owner_of.iter().map(|&w| w as usize).collect();
    if owner_of.len() != total_ranks {
        return Err(WilkinsError::Comm(format!(
            "owner map covers {} ranks, world has {total_ranks}",
            owner_of.len()
        )));
    }
    let transport = Arc::new(SocketTransport::new(
        my_id,
        owner_of,
        peers,
        Arc::clone(&mailboxes),
        shm_pool,
    ));
    // Mesh beat timer: prove this worker alive on every link even
    // when its ranks send nothing, so idle peers' liveness deadlines
    // only ever fire on real deaths. The weak handle stops the timer
    // when the world (and its transport) drops — no beat thread, no
    // stop flag.
    if let Some((interval, _)) = liveness {
        io.add_mesh_beat(Arc::downgrade(&transport), interval);
    }
    let world = World::with_transport(total_ranks, mailboxes, transport);
    Ok(MeshWorld { world, io: io.clone() })
}
