//! Runtime tests against the real AOT artifacts. These require
//! `make artifacts` to have run; they are skipped (with a note) when
//! the artifacts directory is absent so plain `cargo test` still works.

use std::path::PathBuf;

use super::Engine;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Engine::default_dir();
    let dir = if dir.is_relative() {
        // Tests run from the crate root.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir)
    } else {
        dir
    };
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime test: {} missing (run `make artifacts`)", dir.display());
        None
    }
}

#[test]
fn engine_reports_signatures() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let h = engine.handle();
    let sig = h.signature("md_step").unwrap();
    assert_eq!(sig.inputs.len(), 2);
    assert_eq!(sig.inputs[0].dims, vec![4096, 3]);
    assert!(h.signature("nope").is_err());
}

#[test]
fn nyx_step_executes_and_conserves_mass() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let h = engine.handle();
    let n = 64 * 64 * 64;
    // Deterministic pseudo-random density around 1.0.
    let den: Vec<f32> = (0..n)
        .map(|i| 1.0 + 0.3 * (((i * 2654435761_usize) % 1000) as f32 / 1000.0 - 0.5))
        .collect();
    let total0: f64 = den.iter().map(|&x| x as f64).sum();
    let out = h.run("nyx_step", vec![den]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), n);
    let total1: f64 = out[0].iter().map(|&x| x as f64).sum();
    assert!((total1 - total0).abs() / total0 < 1e-4, "{total0} vs {total1}");
    assert!(out[0].iter().all(|x| x.is_finite() && *x >= 0.0));
}

#[test]
fn halo_finder_counts_isolated_peak() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let h = engine.handle();
    let n = 64 * 64 * 64;
    let mut den = vec![0.0f32; n];
    den[(32 * 64 + 32) * 64 + 32] = 5.0;
    let out = h.run("halo_finder", vec![den, vec![1.0]]).unwrap();
    assert_eq!(out.len(), 2);
    let stats = &out[1];
    assert_eq!(stats[0], 1.0, "one halo");
    assert_eq!(stats[1], 5.0, "its mass");
    assert_eq!(stats[2], 5.0, "peak density");
}

#[test]
fn md_step_and_detector_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let h = engine.handle();
    // 16^3 jittered lattice in an 18.0 box (mirrors python tests).
    let nside = 16;
    let box_ = 18.0f32;
    let spacing = box_ / nside as f32;
    let mut pos = Vec::with_capacity(4096 * 3);
    for i in 0..nside {
        for j in 0..nside {
            for k in 0..nside {
                let jit = |v: usize| ((v * 2654435761) % 97) as f32 / 97.0 * 0.1 - 0.05;
                pos.push((i as f32 + 0.5) * spacing + jit(i * 256 + j) * spacing);
                pos.push((j as f32 + 0.5) * spacing + jit(j * 256 + k) * spacing);
                pos.push((k as f32 + 0.5) * spacing + jit(k * 256 + i) * spacing);
            }
        }
    }
    let vel = vec![0.0f32; 4096 * 3];
    let out = h.run("md_step", vec![pos.clone(), vel]).unwrap();
    assert_eq!(out.len(), 2);
    let (p1, v1) = (&out[0], &out[1]);
    assert!(p1.iter().all(|x| x.is_finite() && *x >= 0.0 && *x < box_));
    assert!(v1.iter().all(|x| x.is_finite()));
    // Atoms moved.
    let moved = p1
        .iter()
        .zip(&pos)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(moved > 0.0);

    let det = h.run("diamond_detector", vec![out[0].clone()]).unwrap();
    let stats = &det[0];
    assert_eq!(stats.len(), 4);
    assert_eq!(stats[3], 4096.0);
    assert!(stats[0] >= 0.0 && stats[0] <= 4096.0);
}

#[test]
fn shape_validation_errors() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let h = engine.handle();
    assert!(h.run("nyx_step", vec![vec![0.0; 7]]).is_err());
    assert!(h.run("nyx_step", vec![]).is_err());
    assert!(h.run("unknown", vec![]).is_err());
}

#[test]
fn handle_is_cloneable_across_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let h = engine.handle();
            std::thread::spawn(move || {
                let den = vec![1.0f32; 64 * 64 * 64];
                let out = h.run("nyx_step", vec![den]).unwrap();
                assert_eq!(out[0].len(), 64 * 64 * 64);
            })
        })
        .collect();
    for t in handles {
        t.join().unwrap();
    }
}
