//! PJRT runtime (S10): loads the AOT-compiled `artifacts/*.hlo.txt`
//! payloads and executes them from the Rust request path.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so the engine owns it on a
//! dedicated thread; rank threads talk to it through a cloneable
//! [`EngineHandle`]. Executables are compiled once and cached — the
//! compile cost never lands on the workflow hot path. Requests execute
//! in arrival order, which matches the one-accelerator-per-node model
//! of the testbed.

mod manifest;

pub use manifest::{Signature, TensorSig};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Mutex, OnceLock};
use std::thread;

use crate::error::{Result, WilkinsError};

/// Process-wide AOT engine cache, keyed by artifacts directory.
///
/// Ensembles run many workflow instances in one process; without
/// sharing, every instance would start its own engine thread and
/// recompile identical `*.hlo.txt` payloads. [`shared_engine`] hands
/// all of them handles to one [`Engine`] per artifacts directory, so
/// each artifact compiles and loads once for the whole ensemble.
static SHARED_ENGINES: OnceLock<Mutex<HashMap<PathBuf, Engine>>> = OnceLock::new();

/// Get (or lazily start) the process-shared engine for an artifacts
/// directory. The engine — and its compiled-executable cache — stays
/// alive for the rest of the process, which is exactly what a workflow
/// launcher wants: the compile cost is paid once, never per instance.
pub fn shared_engine(artifacts_dir: &Path) -> Result<EngineHandle> {
    let map = SHARED_ENGINES.get_or_init(|| Mutex::new(HashMap::new()));
    let key = artifacts_dir
        .canonicalize()
        .unwrap_or_else(|_| artifacts_dir.to_path_buf());
    let mut engines = map
        .lock()
        .map_err(|_| WilkinsError::Runtime("shared engine cache poisoned".into()))?;
    if let Some(e) = engines.get(&key) {
        return Ok(e.handle());
    }
    let engine = Engine::start(artifacts_dir)?;
    let handle = engine.handle();
    engines.insert(key, engine);
    Ok(handle)
}

enum EngineMsg {
    Run {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Signature {
        name: String,
        reply: mpsc::Sender<Result<Signature>>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineMsg>,
}

impl EngineHandle {
    /// Execute artifact `name` with flat f32 inputs; returns the flat
    /// f32 outputs (one Vec per tuple element).
    pub fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Run { name: name.to_string(), inputs, reply })
            .map_err(|_| WilkinsError::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| WilkinsError::Runtime("engine thread dropped reply".into()))?
    }

    /// I/O signature of an artifact (from the manifest).
    pub fn signature(&self, name: &str) -> Result<Signature> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Signature { name: name.to_string(), reply })
            .map_err(|_| WilkinsError::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| WilkinsError::Runtime("engine thread dropped reply".into()))?
    }
}

/// The engine: owns the PJRT client and compiled executables.
pub struct Engine {
    tx: mpsc::Sender<EngineMsg>,
    join: Option<thread::JoinHandle<()>>,
}

impl Engine {
    /// Start the engine thread over an artifacts directory (must
    /// contain manifest.tsv + *.hlo.txt from `make artifacts`).
    pub fn start(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = manifest::load(&artifacts_dir.join("manifest.tsv"))?;
        let dir = artifacts_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<EngineMsg>();
        let join = thread::Builder::new()
            .name("wilkins-pjrt".into())
            .spawn(move || engine_main(dir, manifest, rx))
            .map_err(|e| WilkinsError::Runtime(format!("spawn engine: {e}")))?;
        Ok(Engine { tx, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        EngineHandle { tx: self.tx.clone() }
    }

    /// Default artifacts directory: $WILKINS_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("WILKINS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(EngineMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_main(
    dir: PathBuf,
    manifest: HashMap<String, Signature>,
    rx: mpsc::Receiver<EngineMsg>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the same error.
            let msg = format!("PJRT CPU client failed: {e}");
            for m in rx {
                match m {
                    EngineMsg::Run { reply, .. } => {
                        let _ = reply.send(Err(WilkinsError::Runtime(msg.clone())));
                    }
                    EngineMsg::Signature { reply, .. } => {
                        let _ = reply.send(Err(WilkinsError::Runtime(msg.clone())));
                    }
                    EngineMsg::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    for m in rx {
        match m {
            EngineMsg::Shutdown => break,
            EngineMsg::Signature { name, reply } => {
                let sig = manifest
                    .get(&name)
                    .cloned()
                    .ok_or_else(|| WilkinsError::Runtime(format!("unknown artifact {name}")));
                let _ = reply.send(sig);
            }
            EngineMsg::Run { name, inputs, reply } => {
                let res = run_one(&dir, &manifest, &client, &mut cache, &name, inputs);
                let _ = reply.send(res);
            }
        }
    }
}

fn run_one(
    dir: &Path,
    manifest: &HashMap<String, Signature>,
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    inputs: Vec<Vec<f32>>,
) -> Result<Vec<Vec<f32>>> {
    let sig = manifest
        .get(name)
        .ok_or_else(|| WilkinsError::Runtime(format!("unknown artifact {name}")))?;
    if inputs.len() != sig.inputs.len() {
        return Err(WilkinsError::Runtime(format!(
            "{name}: expected {} inputs, got {}",
            sig.inputs.len(),
            inputs.len()
        )));
    }
    for (i, (buf, ts)) in inputs.iter().zip(&sig.inputs).enumerate() {
        if buf.len() != ts.element_count() {
            return Err(WilkinsError::Runtime(format!(
                "{name}: input {i} needs {} elements ({}), got {}",
                ts.element_count(),
                ts,
                buf.len()
            )));
        }
    }
    if !cache.contains_key(name) {
        let path = dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(WilkinsError::Runtime(format!(
                "artifact {} missing; run `make artifacts`",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| WilkinsError::Runtime("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        cache.insert(name.to_string(), exe);
    }
    let exe = &cache[name];
    let mut lits = Vec::with_capacity(inputs.len());
    for (buf, ts) in inputs.iter().zip(&sig.inputs) {
        let dims: Vec<i64> = ts.dims.iter().map(|&d| d as i64).collect();
        lits.push(xla::Literal::vec1(buf).reshape(&dims)?);
    }
    let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: the root is always a tuple.
    let parts = result.to_tuple()?;
    if parts.len() != sig.outputs.len() {
        return Err(WilkinsError::Runtime(format!(
            "{name}: manifest says {} outputs, executable returned {}",
            sig.outputs.len(),
            parts.len()
        )));
    }
    let mut out = Vec::with_capacity(parts.len());
    for p in parts {
        out.push(p.to_vec::<f32>()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests;
