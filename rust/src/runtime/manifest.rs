//! artifacts/manifest.tsv parsing: per-artifact I/O signatures written
//! by python/compile/aot.py ("name \t ins \t outs", shapes like
//! `f32[4096,3]` joined with `;`).

use std::collections::HashMap;
use std::fmt;
use std::path::Path;

use crate::error::{Result, WilkinsError};

/// One tensor's dtype + shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSig {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn parse(s: &str) -> Result<TensorSig> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| WilkinsError::Runtime(format!("bad tensor sig {s:?}")))?;
        let dims_s = rest
            .strip_suffix(']')
            .ok_or_else(|| WilkinsError::Runtime(format!("bad tensor sig {s:?}")))?;
        let dims = if dims_s.is_empty() {
            Vec::new()
        } else {
            dims_s
                .split(',')
                .map(|d| {
                    d.trim().parse::<usize>().map_err(|e| {
                        WilkinsError::Runtime(format!("bad dim {d:?} in {s:?}: {e}"))
                    })
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSig { dtype: dtype.to_string(), dims })
    }
}

impl fmt::Display for TensorSig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}]",
            self.dtype,
            self.dims
                .iter()
                .map(ToString::to_string)
                .collect::<Vec<_>>()
                .join(",")
        )
    }
}

/// Full I/O signature of one artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Signature {
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

fn parse_list(s: &str) -> Result<Vec<TensorSig>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(';').map(TensorSig::parse).collect()
}

pub fn load(path: &Path) -> Result<HashMap<String, Signature>> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        WilkinsError::Runtime(format!(
            "cannot read {} (run `make artifacts`): {e}",
            path.display()
        ))
    })?;
    let mut out = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split('\t');
        let (Some(name), Some(ins), Some(outs)) =
            (parts.next(), parts.next(), parts.next())
        else {
            return Err(WilkinsError::Runtime(format!(
                "manifest line {} malformed: {line:?}",
                lineno + 1
            )));
        };
        out.insert(
            name.to_string(),
            Signature { inputs: parse_list(ins)?, outputs: parse_list(outs)? },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_sig_parse_roundtrip() {
        let t = TensorSig::parse("f32[4096,3]").unwrap();
        assert_eq!(t.dtype, "f32");
        assert_eq!(t.dims, vec![4096, 3]);
        assert_eq!(t.element_count(), 12288);
        assert_eq!(t.to_string(), "f32[4096,3]");
    }

    #[test]
    fn scalar_sig() {
        let t = TensorSig::parse("f32[]").unwrap();
        assert_eq!(t.dims, Vec::<usize>::new());
        assert_eq!(t.element_count(), 1);
    }

    #[test]
    fn bad_sigs_rejected() {
        assert!(TensorSig::parse("f32").is_err());
        assert!(TensorSig::parse("f32[a]").is_err());
        assert!(TensorSig::parse("f32[1,2").is_err());
    }

    #[test]
    fn manifest_load() {
        let dir = std::env::temp_dir().join("wilkins-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.tsv");
        std::fs::write(&p, "md\tf32[8,3];f32[8,3]\tf32[8,3];f32[8,3]\nhalo\tf32[4,4,4];f32[1]\tf32[4,4,4];f32[4]\n").unwrap();
        let m = load(&p).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m["md"].inputs.len(), 2);
        assert_eq!(m["halo"].outputs[1].dims, vec![4]);
    }
}
