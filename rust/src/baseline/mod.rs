//! LowFive-standalone baseline (S14): the hand-written coupling of
//! Peterka et al. [28] that the paper's overhead experiment (Sec.
//! 4.1.1, Fig. 4) compares Wilkins against.
//!
//! No YAML, no graph, no coordinator, no driver: the producer and
//! consumer groups, their communicators and the channel are wired by
//! hand, exactly like the reference code the LowFive paper shipped.
//! Both this and the Wilkins run move identical bytes through the same
//! transport, so their difference is precisely the workflow-system
//! overhead.

use std::sync::Arc;
use std::thread;

use crate::comm::{InterComm, World};
use crate::error::Result;
use crate::lowfive::{
    split_rows, AttrValue, DType, InChannel, OutChannel, RouteTable, Vol,
};


/// Sizes of the synthetic weak-scaling benchmark.
#[derive(Debug, Clone, Copy)]
pub struct SyntheticSize {
    pub grid_per_proc: u64,
    pub particles_per_proc: u64,
    pub steps: u64,
}

/// Run the hand-written 2-task coupling: `m` producer ranks write the
/// grid + particles datasets, `n` consumer ranks read their row splits.
/// Returns the wall time in seconds.
pub fn run_standalone(m: usize, n: usize, size: SyntheticSize) -> Result<f64> {
    let world = World::new(m + n);
    let pid = world.alloc_comm_id();
    let cid = world.alloc_comm_id();
    let ioid = world.alloc_comm_id();
    let chid = world.alloc_comm_id();
    let prod_ranks: Vec<usize> = (0..m).collect();
    let cons_ranks: Vec<usize> = (m..m + n).collect();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for g in 0..m + n {
        let world = world.clone();
        let prod_ranks = prod_ranks.clone();
        let cons_ranks = cons_ranks.clone();
        let workdir = std::env::temp_dir().join("wilkins-baseline");
        handles.push(thread::spawn(move || -> Result<()> {
            if g < m {
                let local = world.comm_from_ranks(pid, &prod_ranks, g);
                let io = world.comm_from_ranks(ioid, &prod_ranks, g);
                let mut vol = Vol::new(local.clone(), workdir);
                vol.set_io_comm(Some(io));
                let ic = InterComm::new(local, chid, cons_ranks.clone());
                vol.add_out_channel(OutChannel::new(
                    Some(ic),
                    "outfile.h5",
                    RouteTable::memory(),
                ));
                producer_body(&mut vol, g, m, size)?;
                vol.finalize_producer()
            } else {
                let local = world.comm_from_ranks(cid, &cons_ranks, g - m);
                let mut vol = Vol::new(local.clone(), workdir);
                let ic = InterComm::new(local, chid, prod_ranks.clone());
                vol.add_in_channel(InChannel::new(
                    Some(ic),
                    "outfile.h5",
                    RouteTable::memory(),
                ));
                consumer_body(&mut vol, g - m, n, size)?;
                vol.finalize_consumer()
            }
        }));
    }
    let results: Vec<Result<()>> = handles
        .into_iter()
        .map(|h| h.join().expect("baseline rank panicked"))
        .collect();
    for r in results {
        r?;
    }
    Ok(t0.elapsed().as_secs_f64())
}

fn producer_body(vol: &mut Vol, rank: usize, m: usize, size: SyntheticSize) -> Result<()> {
    let gdims = [size.grid_per_proc * m as u64];
    let pdims = [size.particles_per_proc * m as u64, 3];
    let gslab = split_rows(&gdims, m)[rank].clone();
    let pslab = split_rows(&pdims, m)[rank].clone();
    for step in 0..size.steps {
        let goff = gslab.offset[0];
        let grid = crate::tasks::gen_u64_bytes(gslab.count[0], |i| (goff + i) * 10 + step);
        let parts =
            crate::tasks::gen_f32_bytes(pslab.count[0] * 3, |k| (k % 1000) as f32);
        vol.file_create("outfile.h5")?;
        vol.attr_write("outfile.h5", "timestep", AttrValue::Int(step as i64))?;
        vol.dataset_create("outfile.h5", "/group1/grid", DType::U64, &gdims)?;
        vol.dataset_create("outfile.h5", "/group1/particles", DType::F32, &pdims)?;
        vol.dataset_write("outfile.h5", "/group1/grid", gslab.clone(), grid)?;
        vol.dataset_write("outfile.h5", "/group1/particles", pslab.clone(), parts)?;
        vol.file_close("outfile.h5")?;
    }
    Ok(())
}

fn consumer_body(vol: &mut Vol, rank: usize, n: usize, size: SyntheticSize) -> Result<()> {
    for _ in 0..size.steps {
        let name = vol.file_open("outfile.h5")?;
        for dset in vol.consumer_file(&name)?.dataset_names() {
            let meta = vol.dataset_meta(&name, &dset)?;
            let want = split_rows(&meta.dims, n)[rank].clone();
            vol.dataset_read(&name, &dset, &want)?;
        }
        vol.file_close(&name)?;
    }
    Ok(())
}

/// The Arc is unused but keeps the signature parallel to coordinator
/// internals for profiling comparisons.
pub type SharedWorld = Arc<World>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standalone_coupling_completes() {
        let secs = run_standalone(
            3,
            1,
            SyntheticSize { grid_per_proc: 1000, particles_per_proc: 1000, steps: 2 },
        )
        .unwrap();
        assert!(secs > 0.0);
    }

    #[test]
    fn standalone_scales_to_more_ranks() {
        let secs = run_standalone(
            12,
            4,
            SyntheticSize { grid_per_proc: 500, particles_per_proc: 500, steps: 1 },
        )
        .unwrap();
        assert!(secs > 0.0);
    }
}
