//! Wilkins-master (S9, paper Sec. 3.3): the workflow driver.
//!
//! Reads the configuration, builds the graph, partitions the SPMD
//! world into restricted per-task worlds, creates the LowFive objects
//! and the intercommunicators between coupled tasks, wires flow
//! control and custom actions, launches every rank, and joins the
//! whole workflow. Users never touch this code — everything is driven
//! by the YAML file, exactly as in the paper.
//!
//! One [`Wilkins`] drives one workflow instance. To co-schedule many
//! instances against a shared rank budget, use the parallel entry
//! point [`Ensemble::run`](crate::ensemble::Ensemble::run).

pub(crate) mod report;

pub use report::{FaultStats, NodeReport, RunReport};

// The campaign layer above single runs; re-exported here so the two
// drivers (one instance / many instances) are found side by side.
pub use crate::ensemble::{Ensemble, EnsembleReport};

use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::actions::ActionRegistry;
use crate::comm::{InterComm, World};
use crate::config::{ConsumerKind, WorkflowConfig};
use crate::error::{Result, WilkinsError};
use crate::graph::WorkflowGraph;
use crate::henson::{drive_rank, Registry, Role, TaskContext};
use crate::lowfive::{InChannel, OutChannel, Vol};
use crate::metrics::Recorder;
use crate::runtime::EngineHandle;

/// The coordinator. Build one per workflow run.
pub struct Wilkins {
    cfg: WorkflowConfig,
    graph: WorkflowGraph,
    registry: Arc<Registry>,
    actions: ActionRegistry,
    engine: Option<EngineHandle>,
    workdir: PathBuf,
    time_scale: f64,
    recorder: Arc<Recorder>,
}

impl Wilkins {
    pub fn new(cfg: WorkflowConfig, registry: Registry) -> Result<Wilkins> {
        let graph = WorkflowGraph::build(&cfg)?;
        let workdir = cfg
            .workdir
            .clone()
            .map(PathBuf::from)
            .unwrap_or_else(|| {
                std::env::temp_dir().join(format!("wilkins-run-{}", std::process::id()))
            });
        Ok(Wilkins {
            cfg,
            graph,
            registry: Arc::new(registry),
            actions: ActionRegistry::with_builtins(),
            engine: None,
            workdir,
            time_scale: 1.0,
            recorder: Arc::new(Recorder::new()),
        })
    }

    pub fn from_yaml_str(src: &str, registry: Registry) -> Result<Wilkins> {
        Wilkins::new(WorkflowConfig::from_yaml_str(src)?, registry)
    }

    pub fn from_yaml_file(path: &std::path::Path, registry: Registry) -> Result<Wilkins> {
        Wilkins::new(WorkflowConfig::from_yaml_file(path)?, registry)
    }

    /// Attach the AOT compute engine (science payloads need it).
    pub fn with_engine(mut self, engine: EngineHandle) -> Wilkins {
        self.engine = Some(engine);
        self
    }

    /// Scale sleep-emulated compute: wall-seconds per paper-second.
    pub fn with_time_scale(mut self, s: f64) -> Wilkins {
        self.time_scale = s;
        self
    }

    pub fn with_workdir(mut self, dir: PathBuf) -> Wilkins {
        self.workdir = dir;
        self
    }

    /// Register a custom action (the user's "Python script").
    pub fn with_action(
        mut self,
        script: &str,
        func: &str,
        f: crate::actions::ActionFn,
    ) -> Wilkins {
        self.actions.register(script, func, f);
        self
    }

    pub fn graph(&self) -> &WorkflowGraph {
        &self.graph
    }

    pub fn recorder(&self) -> Arc<Recorder> {
        Arc::clone(&self.recorder)
    }

    /// Launch the workflow and block until every rank finishes.
    pub fn run(&self) -> Result<RunReport> {
        let g = &self.graph;
        let world = World::new(g.total_ranks);
        let hosted: Vec<usize> = (0..g.total_ranks).collect();
        let t0 = Instant::now();
        let outcomes = self.run_hosted(&world, &hosted)?;
        report::build(g, outcomes, t0.elapsed(), world.bytes_sent(), world.msgs_sent())
    }

    /// Run only the `hosted` subset of global ranks on this process,
    /// against a caller-supplied `world` (the multi-process substrate
    /// in [`crate::net`] passes a socket-backed world; [`Wilkins::run`]
    /// passes a fresh in-memory world hosting every rank).
    ///
    /// Communicator ids are allocated from `world` in a deterministic
    /// order (per-node local + I/O comms, then per-channel ids), so
    /// every process that builds the same graph against a fresh world
    /// assigns identical ids — the cross-process analogue of the
    /// coordinator allocating ids once before launch.
    pub(crate) fn run_hosted(
        &self,
        world: &World,
        hosted: &[usize],
    ) -> Result<Vec<report::RankOutcome>> {
        let g = &self.graph;

        // Pre-allocate communicator ids deterministically: one local +
        // one I/O comm per node, one id per channel.
        let local_ids: Vec<u64> = g.nodes.iter().map(|_| world.alloc_comm_id()).collect();
        let io_ids: Vec<u64> = g.nodes.iter().map(|_| world.alloc_comm_id()).collect();
        let chan_ids: Vec<u64> = g.channels.iter().map(|_| world.alloc_comm_id()).collect();

        // Resolve task codes and actions up-front for fast failure.
        let mut codes = Vec::with_capacity(g.nodes.len());
        let mut node_actions = Vec::with_capacity(g.nodes.len());
        for node in &g.nodes {
            let t = &self.cfg.tasks[node.task_idx];
            codes.push(self.registry.get(&t.func)?);
            node_actions.push(match &t.actions {
                Some((s, f)) => Some(self.actions.get(s, f)?),
                None => None,
            });
        }
        std::fs::create_dir_all(&self.workdir)?;

        let mut handles = Vec::with_capacity(hosted.len());
        for &rank in hosted {
            let node_idx = g
                .node_of_rank(rank)
                .ok_or_else(|| WilkinsError::Graph(format!("rank {rank} unassigned")))?;
            let node = g.nodes[node_idx].clone();
            let task = self.cfg.tasks[node.task_idx].clone();
            let code = Arc::clone(&codes[node_idx]);
            let action = node_actions[node_idx].clone();
            let world = world.clone();
            let graph = g.clone();
            let chan_ids = chan_ids.clone();
            let local_id = local_ids[node_idx];
            let io_id = io_ids[node_idx];
            let engine = self.engine.clone();
            let recorder = Arc::clone(&self.recorder);
            let workdir = self.workdir.clone();
            let time_scale = self.time_scale;
            let builder = thread::Builder::new()
                .name(format!("wk-{}-{}", node.name, rank - node.first_rank))
                .stack_size(2 << 20);
            let h = builder
                .spawn(move || -> Result<report::RankOutcome> {
                    let local_rank = rank - node.first_rank;
                    let ranks: Vec<usize> = node.ranks().collect();
                    let local = world.comm_from_ranks(local_id, &ranks, local_rank);
                    let mut vol = Vol::new(local.clone(), workdir);
                    vol.set_recorder(Arc::clone(&recorder), rank);
                    if local_rank < node.nwriters {
                        let io_ranks: Vec<usize> = node.io_ranks().collect();
                        let io = world.comm_from_ranks(io_id, &io_ranks, local_rank);
                        vol.set_io_comm(Some(io));
                    }

                    // Out-channels: this node as producer. The
                    // intercomm exists when any dataset of the
                    // channel routes through memory.
                    for ci in graph.out_channels_of(node_idx) {
                        let ch = &graph.channels[ci];
                        let consumer = &graph.nodes[ch.consumer];
                        let ic = if local_rank < node.nwriters && ch.routes.any_memory()
                        {
                            Some(InterComm::new(
                                local.clone(),
                                chan_ids[ci],
                                consumer.ranks().collect(),
                            ))
                        } else {
                            None
                        };
                        vol.add_out_channel(
                            OutChannel::new(ic, &ch.out_pattern, ch.routes.clone())
                                .with_policy(ch.flow),
                        );
                    }
                    // In-channels: this node as consumer. Remote group
                    // is the producer's I/O ranks only.
                    for ci in graph.in_channels_of(node_idx) {
                        let ch = &graph.channels[ci];
                        let producer = &graph.nodes[ch.producer];
                        let ic = if ch.routes.any_memory() {
                            Some(InterComm::new(
                                local.clone(),
                                chan_ids[ci],
                                producer.io_ranks().collect(),
                            ))
                        } else {
                            None
                        };
                        vol.add_in_channel(InChannel::new(
                            ic,
                            &ch.in_pattern,
                            ch.routes.clone(),
                        ));
                    }

                    if let Some(action) = action {
                        action(&mut vol, local_rank);
                    }

                    let role = match (
                        graph.out_channels_of(node_idx).is_empty(),
                        graph.in_channels_of(node_idx).is_empty(),
                    ) {
                        (false, true) => Role::Producer,
                        (true, false) => Role::Consumer,
                        _ => Role::Intermediate,
                    };
                    let kind = match task.consumer_kind {
                        ConsumerKind::Stateless => ConsumerKind::Stateless,
                        ConsumerKind::Stateful => ConsumerKind::Stateful,
                    };
                    let mut ctx = TaskContext {
                        comm: local,
                        vol,
                        instance: node.instance,
                        nwriters: node.nwriters,
                        name: node.name.clone(),
                        params: task.params.clone(),
                        engine,
                        recorder: Some(recorder),
                        global_rank: rank,
                        time_scale,
                    };
                    let res = drive_rank(code, role, kind, &mut ctx);
                    Ok(report::RankOutcome {
                        node: node_idx,
                        stats: ctx.vol.stats.clone(),
                        error: res.err().map(|e| e.to_string()),
                    })
                })
                .map_err(|e| WilkinsError::Task(format!("spawn rank {rank}: {e}")))?;
            handles.push(h);
        }

        let mut outcomes = Vec::with_capacity(handles.len());
        for h in handles {
            match h.join() {
                Ok(Ok(o)) => outcomes.push(o),
                Ok(Err(e)) => return Err(e),
                Err(_) => {
                    return Err(WilkinsError::Task("rank thread panicked".into()))
                }
            }
        }
        Ok(outcomes)
    }
}
