//! Run reports: per-node transport statistics + workflow totals, the
//! raw material for every table/figure bench.
//!
//! Counter plumbing is registry-driven (see [`crate::obs::counters`]):
//! a [`NodeReport`] carries one merged [`VolStats`] per task node and
//! merging/JSON/wire all iterate [`VolStats::DEFS`] instead of naming
//! fields, so a counter added to the family shows up everywhere at
//! once.

use std::time::Duration;

use crate::error::{Result, WilkinsError};
use crate::graph::WorkflowGraph;
use crate::lowfive::VolStats;
use crate::obs::json::{Arr, Obj};
use crate::obs::{merge_values, CounterDef, TelemetrySummary, GLOBAL_DEFS};

/// One rank's raw result: crate-visible so the multi-process substrate
/// (`net::`) can ship outcomes across the wire and merge them with
/// [`build`] exactly like the single-process path.
pub(crate) struct RankOutcome {
    pub node: usize,
    pub stats: VolStats,
    pub error: Option<String>,
}

/// Aggregated statistics of one task instance: the node's identity
/// plus its rank-merged counter family. Derefs to [`VolStats`], so
/// counters read as direct fields (`report.nodes[0].bytes_served`).
#[derive(Debug, Clone)]
pub struct NodeReport {
    /// Task name from the workflow graph.
    pub name: String,
    /// Ranks the task ran on.
    pub nprocs: usize,
    /// Counters merged across the node's ranks per
    /// [`VolStats::DEFS`] semantics.
    pub stats: VolStats,
}

impl std::ops::Deref for NodeReport {
    type Target = VolStats;

    fn deref(&self) -> &VolStats {
        &self.stats
    }
}

/// Fault-tolerance counters of one run or campaign. All zero on a
/// healthy run; the `faults:` report line is emitted unconditionally
/// so downstream greps never miss the column.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Workers declared dead (socket closed or heartbeat deadline
    /// exceeded) while the coordinator depended on them.
    pub lost_workers: u64,
    /// Instance dispatches that re-ran work a lost worker had in
    /// flight.
    pub retries: u64,
    /// Heartbeat intervals that elapsed with no traffic from a worker
    /// that later proved alive (late beats; zero on a healthy link).
    pub heartbeat_misses: u64,
    /// Stale or duplicate `InstanceDone` replies dropped by the
    /// idempotency-key check instead of being double-counted.
    pub dup_done: u64,
}

impl FaultStats {
    /// The registered counter family, in wire/JSON order (append
    /// only). Fault counters all sum across runs of a campaign.
    pub const DEFS: &'static [CounterDef] = &[
        CounterDef::sum("lost_workers"),
        CounterDef::sum("retries"),
        CounterDef::sum("heartbeat_misses"),
        CounterDef::sum("dup_done"),
    ];

    /// The family's values in [`FaultStats::DEFS`] order.
    pub fn counter_values(&self) -> Vec<u64> {
        vec![self.lost_workers, self.retries, self.heartbeat_misses, self.dup_done]
    }

    /// Rebuild from [`FaultStats::DEFS`]-ordered values.
    pub fn from_counter_values(vals: &[u64]) -> FaultStats {
        assert_eq!(vals.len(), Self::DEFS.len(), "FaultStats counter count mismatch");
        FaultStats {
            lost_workers: vals[0],
            retries: vals[1],
            heartbeat_misses: vals[2],
            dup_done: vals[3],
        }
    }

    /// Did any fault machinery engage?
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }

    /// The greppable one-line summary (shared by workflow and
    /// ensemble reports; ci/check.sh asserts on it). Registry-driven:
    /// one `name=value` column per registered counter.
    pub fn render_line(&self) -> String {
        let mut s = String::from("faults:");
        for (d, v) in Self::DEFS.iter().zip(self.counter_values()) {
            s.push_str(&format!(" {}={v}", d.name));
        }
        s.push('\n');
        s
    }

    /// Accumulate another run's counters into this one (registered
    /// semantics: all sums).
    pub fn absorb(&mut self, other: &FaultStats) {
        let mut vals = self.counter_values();
        merge_values(&mut vals, &other.counter_values(), Self::DEFS);
        *self = FaultStats::from_counter_values(&vals);
    }
}

/// The result of a workflow run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub elapsed: Duration,
    pub total_ranks: usize,
    pub bytes_sent: u64,
    pub msgs_sent: u64,
    pub nodes: Vec<NodeReport>,
    /// Fault-tolerance counters; all zero on a healthy run.
    pub faults: FaultStats,
    /// Live worker telemetry collected while the run executed (empty
    /// for single-process runs and on worker-side partial reports —
    /// only the coordinator that hosts a pool fills it in).
    pub telemetry: TelemetrySummary,
}

impl RunReport {
    pub fn node(&self, name: &str) -> Option<&NodeReport> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Sum one registered [`VolStats`] counter across all nodes
    /// (`0` for names not in the registry).
    pub fn sum_counter(&self, name: &str) -> u64 {
        self.nodes
            .iter()
            .filter_map(|n| n.stats.counter(name))
            .fold(0, |a, v| a.saturating_add(v))
    }

    /// Max of one registered [`VolStats`] counter across all nodes.
    pub fn max_counter(&self, name: &str) -> u64 {
        self.nodes.iter().filter_map(|n| n.stats.counter(name)).max().unwrap_or(0)
    }

    /// Pretty table for the CLI. The `flow:`/`dataplane:`/`wire:`/
    /// `faults:` summary lines are emitted *unconditionally* (zeros
    /// included) so downstream greps and parsers always find every
    /// column.
    pub fn render(&self) -> String {
        let mut s = format!(
            "workflow completed in {:.3}s  ({} ranks, {} msgs, {:.1} MiB sent)\n",
            self.elapsed.as_secs_f64(),
            self.total_ranks,
            self.msgs_sent,
            self.bytes_sent as f64 / (1024.0 * 1024.0)
        );
        s.push_str(&format!(
            "{:<24} {:>6} {:>8} {:>8} {:>12} {:>8} {:>12} {:>10} {:>10} {:>8} {:>10}\n",
            "task", "procs", "served", "skipped", "bytes_out", "opened", "bytes_in",
            "serve_wait", "open_wait", "dropped", "stalled"
        ));
        for n in &self.nodes {
            s.push_str(&format!(
                "{:<24} {:>6} {:>8} {:>8} {:>12} {:>8} {:>12} {:>9.3}s {:>9.3}s {:>8} {:>9.3}s\n",
                n.name,
                n.nprocs,
                n.files_served,
                n.serves_skipped,
                n.bytes_served,
                n.files_opened,
                n.bytes_read,
                n.serve_wait.as_secs_f64(),
                n.open_wait.as_secs_f64(),
                n.serves_dropped,
                n.stall_wait.as_secs_f64()
            ));
        }
        // Greppable summary lines (ci/check.sh asserts on them).
        let dropped = self.sum_counter("serves_dropped");
        let stalled: f64 = self.nodes.iter().map(|n| n.stall_wait.as_secs_f64()).sum();
        let maxq = self.max_counter("max_queue_depth");
        s.push_str(&format!(
            "flow: dropped={dropped} stalled={stalled:.3}s max_queue_depth={maxq}\n"
        ));
        s.push_str(&format!(
            "dataplane: bytes_shared={} bytes_copied={}\n",
            self.sum_counter("bytes_shared"),
            self.sum_counter("bytes_copied")
        ));
        // alloc_rounds must read 0 once the buffer pool is warm —
        // every nonzero value is a serve round that paid an allocation.
        s.push_str(&format!(
            "wire: alloc_rounds={} bytes_pooled={}\n",
            self.sum_counter("alloc_rounds"),
            self.sum_counter("bytes_pooled")
        ));
        s.push_str(&self.faults.render_line());
        if !self.telemetry.is_empty() {
            s.push_str(&format!(
                "telemetry: frames={} workers={}\n",
                self.telemetry.frames, self.telemetry.workers
            ));
        }
        s
    }

    /// Machine-readable report (schema `wilkins.run_report/1`; see
    /// docs/observability.md). Replaces grep-the-summary-line parsing:
    /// every registered counter appears by name under its node.
    pub fn to_json(&self) -> String {
        let mut nodes = Arr::new();
        for n in &self.nodes {
            let mut counters = Obj::new();
            for (d, v) in VolStats::DEFS.iter().zip(n.stats.counter_values()) {
                counters.field_u64(d.name, v);
            }
            let mut node = Obj::new();
            node.field_str("name", &n.name)
                .field_u64("nprocs", n.nprocs as u64)
                .field_raw("counters", &counters.finish());
            nodes.push_raw(&node.finish());
        }
        let mut faults = Obj::new();
        for (d, v) in FaultStats::DEFS.iter().zip(self.faults.counter_values()) {
            faults.field_u64(d.name, v);
        }
        let mut o = Obj::new();
        o.field_str("schema", "wilkins.run_report/1")
            .field_f64("elapsed_s", self.elapsed.as_secs_f64())
            .field_u64("total_ranks", self.total_ranks as u64)
            .field_u64("bytes_sent", self.bytes_sent)
            .field_u64("msgs_sent", self.msgs_sent)
            .field_raw("nodes", &nodes.finish())
            .field_raw("faults", &faults.finish())
            .field_raw("telemetry", &telemetry_json(&self.telemetry));
        o.finish()
    }
}

/// Serialize a [`TelemetrySummary`] (shared by run and ensemble
/// report JSON).
pub(crate) fn telemetry_json(t: &TelemetrySummary) -> String {
    let mut counters = Obj::new();
    for (i, d) in GLOBAL_DEFS.iter().enumerate() {
        counters.field_u64(d.name, t.counters.get(i).copied().unwrap_or(0));
    }
    let mut o = Obj::new();
    o.field_u64("frames", t.frames)
        .field_u64("workers", t.workers)
        .field_raw("counters", &counters.finish());
    o.finish()
}

pub(crate) fn build(
    graph: &WorkflowGraph,
    outcomes: Vec<RankOutcome>,
    elapsed: Duration,
    bytes_sent: u64,
    msgs_sent: u64,
) -> Result<RunReport> {
    let errors: Vec<String> = outcomes
        .iter()
        .filter_map(|o| {
            o.error
                .as_ref()
                .map(|e| format!("{}: {e}", graph.nodes[o.node].name))
        })
        .collect();
    if !errors.is_empty() {
        return Err(WilkinsError::Task(format!(
            "{} rank(s) failed: {}",
            errors.len(),
            errors.join("; ")
        )));
    }
    let mut nodes: Vec<NodeReport> = graph
        .nodes
        .iter()
        .map(|n| NodeReport {
            name: n.name.clone(),
            nprocs: n.nprocs,
            stats: VolStats::default(),
        })
        .collect();
    for o in outcomes {
        // One registry-driven merge instead of sixteen hand-written
        // field folds: Sum/Max semantics live in VolStats::DEFS.
        nodes[o.node].stats.merge_from(&o.stats);
    }
    Ok(RunReport {
        elapsed,
        total_ranks: graph.total_ranks,
        bytes_sent,
        msgs_sent,
        nodes,
        faults: FaultStats::default(),
        telemetry: TelemetrySummary::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(bytes_served: u64, files_served: u64) -> VolStats {
        VolStats { bytes_served, files_served, ..VolStats::default() }
    }

    fn report_two_nodes() -> RunReport {
        RunReport {
            elapsed: Duration::from_millis(1500),
            total_ranks: 3,
            bytes_sent: 4096,
            msgs_sent: 7,
            nodes: vec![
                NodeReport { name: "prod".into(), nprocs: 2, stats: stats(1024, 4) },
                NodeReport { name: "cons".into(), nprocs: 1, stats: stats(0, 0) },
            ],
            faults: FaultStats::default(),
            telemetry: TelemetrySummary::default(),
        }
    }

    #[test]
    fn deref_exposes_counters_as_fields() {
        let r = report_two_nodes();
        assert_eq!(r.nodes[0].bytes_served, 1024);
        assert_eq!(r.node("prod").unwrap().files_served, 4);
    }

    #[test]
    fn summary_lines_unconditional() {
        let r = report_two_nodes();
        let out = r.render();
        // All four greppable lines appear even when every value is 0.
        for line in ["flow: dropped=0", "dataplane: bytes_shared=0", "wire: alloc_rounds=0", "faults: lost_workers=0"] {
            assert!(out.contains(line), "missing `{line}` in:\n{out}");
        }
    }

    #[test]
    fn fault_line_registry_driven() {
        let f = FaultStats { lost_workers: 1, retries: 2, heartbeat_misses: 3, dup_done: 4 };
        assert_eq!(
            f.render_line(),
            "faults: lost_workers=1 retries=2 heartbeat_misses=3 dup_done=4\n"
        );
        let mut acc = FaultStats::default();
        acc.absorb(&f);
        acc.absorb(&f);
        assert_eq!(acc.counter_values(), vec![2, 4, 6, 8]);
    }

    #[test]
    fn json_report_has_schema_and_counters() {
        let mut r = report_two_nodes();
        r.faults.lost_workers = 1;
        r.telemetry = TelemetrySummary {
            frames: 5,
            workers: 2,
            counters: vec![0; GLOBAL_DEFS.len()],
        };
        let j = r.to_json();
        assert!(j.contains("\"schema\":\"wilkins.run_report/1\""));
        assert!(j.contains("\"bytes_served\":1024"));
        assert!(j.contains("\"lost_workers\":1"));
        assert!(j.contains("\"frames\":5"));
        // Every registered VolStats counter is present by name.
        for d in VolStats::DEFS {
            assert!(j.contains(&format!("\"{}\":", d.name)), "missing counter {}", d.name);
        }
    }

    #[test]
    fn sum_and_max_counters() {
        let mut r = report_two_nodes();
        r.nodes[1].stats.bytes_served = 76;
        r.nodes[1].stats.max_queue_depth = 9;
        assert_eq!(r.sum_counter("bytes_served"), 1100);
        assert_eq!(r.max_counter("max_queue_depth"), 9);
        assert_eq!(r.sum_counter("no_such_counter"), 0);
    }
}
