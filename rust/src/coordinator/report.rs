//! Run reports: per-node transport statistics + workflow totals, the
//! raw material for every table/figure bench.

use std::time::Duration;

use crate::error::{Result, WilkinsError};
use crate::graph::WorkflowGraph;
use crate::lowfive::VolStats;

/// One rank's raw result: crate-visible so the multi-process substrate
/// (`net::`) can ship outcomes across the wire and merge them with
/// [`build`] exactly like the single-process path.
pub(crate) struct RankOutcome {
    pub node: usize,
    pub stats: VolStats,
    pub error: Option<String>,
}

/// Aggregated statistics of one task instance.
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub name: String,
    pub nprocs: usize,
    pub files_served: u64,
    pub serves_skipped: u64,
    /// Rounds discarded by a dropping flow policy (Sec. 3.6).
    pub serves_dropped: u64,
    pub serves_suppressed: u64,
    pub bytes_served: u64,
    /// Serve bytes handed over the zero-copy same-process path.
    pub bytes_shared: u64,
    /// Serve bytes that took the encode/decode round-trip.
    pub bytes_copied: u64,
    /// Encoded serve rounds that had to allocate a fresh reply buffer
    /// (pool misses; zero at steady state).
    pub alloc_rounds: u64,
    /// Bytes encoded into recycled pool buffers (allocation-free).
    pub bytes_pooled: u64,
    pub files_opened: u64,
    pub bytes_read: u64,
    /// Max across ranks (the critical-path wait).
    pub serve_wait: Duration,
    pub open_wait: Duration,
    /// Time the producer stalled on flow credits (max across ranks).
    pub stall_wait: Duration,
    /// High-water mark of any flow round buffer (max across ranks).
    pub max_queue_depth: u64,
}

/// Fault-tolerance counters of one run or campaign. All zero on a
/// healthy run; any nonzero value surfaces as a greppable `faults:`
/// line in the rendered report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Workers declared dead (socket closed or heartbeat deadline
    /// exceeded) while the coordinator depended on them.
    pub lost_workers: u64,
    /// Instance dispatches that re-ran work a lost worker had in
    /// flight.
    pub retries: u64,
    /// Heartbeat intervals that elapsed with no traffic from a worker
    /// that later proved alive (late beats; zero on a healthy link).
    pub heartbeat_misses: u64,
    /// Stale or duplicate `InstanceDone` replies dropped by the
    /// idempotency-key check instead of being double-counted.
    pub dup_done: u64,
}

impl FaultStats {
    /// Did any fault machinery engage?
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }

    /// The greppable one-line summary (shared by workflow and
    /// ensemble reports; ci/check.sh asserts on it).
    pub fn render_line(&self) -> String {
        format!(
            "faults: lost_workers={} retries={} heartbeat_misses={} dup_done={}\n",
            self.lost_workers, self.retries, self.heartbeat_misses, self.dup_done
        )
    }

    /// Accumulate another run's counters into this one.
    pub fn absorb(&mut self, other: &FaultStats) {
        self.lost_workers += other.lost_workers;
        self.retries += other.retries;
        self.heartbeat_misses += other.heartbeat_misses;
        self.dup_done += other.dup_done;
    }
}

/// The result of a workflow run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub elapsed: Duration,
    pub total_ranks: usize,
    pub bytes_sent: u64,
    pub msgs_sent: u64,
    pub nodes: Vec<NodeReport>,
    /// Fault-tolerance counters; all zero on a healthy run.
    pub faults: FaultStats,
}

impl RunReport {
    pub fn node(&self, name: &str) -> Option<&NodeReport> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Pretty table for the CLI.
    pub fn render(&self) -> String {
        let mut s = format!(
            "workflow completed in {:.3}s  ({} ranks, {} msgs, {:.1} MiB sent)\n",
            self.elapsed.as_secs_f64(),
            self.total_ranks,
            self.msgs_sent,
            self.bytes_sent as f64 / (1024.0 * 1024.0)
        );
        s.push_str(&format!(
            "{:<24} {:>6} {:>8} {:>8} {:>12} {:>8} {:>12} {:>10} {:>10} {:>8} {:>10}\n",
            "task", "procs", "served", "skipped", "bytes_out", "opened", "bytes_in",
            "serve_wait", "open_wait", "dropped", "stalled"
        ));
        for n in &self.nodes {
            s.push_str(&format!(
                "{:<24} {:>6} {:>8} {:>8} {:>12} {:>8} {:>12} {:>9.3}s {:>9.3}s {:>8} {:>9.3}s\n",
                n.name,
                n.nprocs,
                n.files_served,
                n.serves_skipped,
                n.bytes_served,
                n.files_opened,
                n.bytes_read,
                n.serve_wait.as_secs_f64(),
                n.open_wait.as_secs_f64(),
                n.serves_dropped,
                n.stall_wait.as_secs_f64()
            ));
        }
        // One greppable flow-control summary (ci/check.sh asserts on
        // it) whenever backpressure actually engaged.
        let dropped: u64 = self.nodes.iter().map(|n| n.serves_dropped).sum();
        let stalled: f64 = self.nodes.iter().map(|n| n.stall_wait.as_secs_f64()).sum();
        let maxq = self.nodes.iter().map(|n| n.max_queue_depth).max().unwrap_or(0);
        // Only when flow control did something beyond the synchronous
        // default (depth-1 block stalls on every serve by definition).
        if dropped > 0 || maxq > 1 {
            s.push_str(&format!(
                "flow: dropped={dropped} stalled={stalled:.3}s max_queue_depth={maxq}\n"
            ));
        }
        // One greppable data-plane summary (ci/check.sh asserts on
        // it): how many serve bytes took the zero-copy same-process
        // path vs the encode/decode round-trip.
        let shared: u64 = self.nodes.iter().map(|n| n.bytes_shared).sum();
        let copied: u64 = self.nodes.iter().map(|n| n.bytes_copied).sum();
        if shared > 0 || copied > 0 {
            s.push_str(&format!("dataplane: bytes_shared={shared} bytes_copied={copied}\n"));
        }
        // One greppable wire summary (ci/check.sh asserts on it):
        // allocation discipline of the encode hot path. alloc_rounds
        // must read 0 once the buffer pool is warm — every nonzero
        // value is a serve round that paid an allocation.
        let alloc_rounds: u64 = self.nodes.iter().map(|n| n.alloc_rounds).sum();
        let pooled: u64 = self.nodes.iter().map(|n| n.bytes_pooled).sum();
        if alloc_rounds > 0 || pooled > 0 {
            s.push_str(&format!("wire: alloc_rounds={alloc_rounds} bytes_pooled={pooled}\n"));
        }
        // One greppable fault summary (ci/check.sh chaos smoke asserts
        // on it) whenever any liveness machinery engaged.
        if self.faults.any() {
            s.push_str(&self.faults.render_line());
        }
        s
    }
}

pub(crate) fn build(
    graph: &WorkflowGraph,
    outcomes: Vec<RankOutcome>,
    elapsed: Duration,
    bytes_sent: u64,
    msgs_sent: u64,
) -> Result<RunReport> {
    let errors: Vec<String> = outcomes
        .iter()
        .filter_map(|o| {
            o.error
                .as_ref()
                .map(|e| format!("{}: {e}", graph.nodes[o.node].name))
        })
        .collect();
    if !errors.is_empty() {
        return Err(WilkinsError::Task(format!(
            "{} rank(s) failed: {}",
            errors.len(),
            errors.join("; ")
        )));
    }
    let mut nodes: Vec<NodeReport> = graph
        .nodes
        .iter()
        .map(|n| NodeReport {
            name: n.name.clone(),
            nprocs: n.nprocs,
            files_served: 0,
            serves_skipped: 0,
            serves_dropped: 0,
            serves_suppressed: 0,
            bytes_served: 0,
            bytes_shared: 0,
            bytes_copied: 0,
            alloc_rounds: 0,
            bytes_pooled: 0,
            files_opened: 0,
            bytes_read: 0,
            serve_wait: Duration::ZERO,
            open_wait: Duration::ZERO,
            stall_wait: Duration::ZERO,
            max_queue_depth: 0,
        })
        .collect();
    for o in outcomes {
        let n = &mut nodes[o.node];
        // files_served/opened are per-rank counters of the same events;
        // report the max (rank counts agree on I/O ranks).
        n.files_served = n.files_served.max(o.stats.files_served);
        n.serves_skipped = n.serves_skipped.max(o.stats.serves_skipped);
        n.serves_dropped = n.serves_dropped.max(o.stats.serves_dropped);
        n.serves_suppressed = n.serves_suppressed.max(o.stats.serves_suppressed);
        n.files_opened = n.files_opened.max(o.stats.files_opened);
        n.bytes_served += o.stats.bytes_served;
        n.bytes_shared += o.stats.bytes_shared;
        n.bytes_copied += o.stats.bytes_copied;
        n.alloc_rounds += o.stats.alloc_rounds;
        n.bytes_pooled += o.stats.bytes_pooled;
        n.bytes_read += o.stats.bytes_read;
        n.serve_wait = n.serve_wait.max(o.stats.serve_wait);
        n.open_wait = n.open_wait.max(o.stats.open_wait);
        n.stall_wait = n.stall_wait.max(o.stats.stall_wait);
        n.max_queue_depth = n.max_queue_depth.max(o.stats.max_queue_depth);
    }
    Ok(RunReport {
        elapsed,
        total_ranks: graph.total_ranks,
        bytes_sent,
        msgs_sent,
        nodes,
        faults: FaultStats::default(),
    })
}
