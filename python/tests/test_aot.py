# AOT contract tests: every entry point lowers to parseable HLO text
# with the manifest signature the Rust runtime expects.

import pytest

from compile import aot, model


@pytest.mark.parametrize("name", sorted(model.ENTRY_POINTS))
def test_lowering_produces_hlo_text(name):
    text = aot.to_hlo_text(model.lowered(name))
    assert "ENTRY" in text
    assert "HloModule" in text
    # return_tuple=True: root is a tuple instruction.
    assert "tuple(" in text or "ROOT" in text


def test_manifest_signatures():
    in_sig, out_sig = aot.signature("md_step")
    assert in_sig == "f32[4096,3];f32[4096,3]"
    assert out_sig == "f32[4096,3];f32[4096,3]"

    in_sig, out_sig = aot.signature("diamond_detector")
    assert in_sig == "f32[4096,3]"
    assert out_sig == "f32[4]"

    in_sig, out_sig = aot.signature("nyx_step")
    assert in_sig == "f32[64,64,64]"
    assert out_sig == "f32[64,64,64]"

    in_sig, out_sig = aot.signature("halo_finder")
    assert in_sig == "f32[64,64,64];f32[1]"
    assert out_sig == "f32[64,64,64];f32[4]"


def test_no_custom_calls_in_hlo():
    """interpret=True Pallas must lower to plain HLO (no Mosaic
    custom-calls the CPU PJRT client cannot execute)."""
    for name in model.ENTRY_POINTS:
        text = aot.to_hlo_text(model.lowered(name))
        assert "custom-call" not in text, name
