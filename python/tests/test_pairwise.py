# Pallas pairwise kernel vs pure-jnp oracle — the core L1 correctness
# signal. Hypothesis sweeps sizes (incl. non-tile-multiples), boxes and
# cutoffs; explicit cases pin down masking, padding and physics edges.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pairwise, pairwise_ref

SET = dict(deadline=None, max_examples=25)


def rel_force_err(f_kernel, f_ref):
    num = jnp.linalg.norm(f_kernel - f_ref, axis=1)
    den = jnp.linalg.norm(f_ref, axis=1) + 1e-6
    return float(jnp.max(num / den))


def rand_pos(seed, n, box):
    return jax.random.uniform(
        jax.random.PRNGKey(seed), (n, 3), minval=0.0, maxval=box)


@settings(**SET)
@given(n=st.integers(1, 97), seed=st.integers(0, 2**31 - 1),
       box=st.floats(2.0, 20.0), cutoff=st.floats(0.5, 3.0))
def test_kernel_matches_ref(n, seed, box, cutoff):
    pos = rand_pos(seed, n, box)
    fk, ck = pairwise(pos, cutoff=cutoff)
    fr, cr = pairwise_ref(pos, cutoff=cutoff)
    assert np.array_equal(np.asarray(ck), np.asarray(cr))
    assert rel_force_err(fk, fr) < 5e-3


@settings(**SET)
@given(n=st.integers(2, 64), seed=st.integers(0, 2**31 - 1),
       tile=st.sampled_from([8, 16, 32, 128]))
def test_tile_size_invariance(n, seed, tile):
    """Result must not depend on the tiling schedule."""
    pos = rand_pos(seed, n, 6.0)
    fa, ca = pairwise(pos, cutoff=1.5, tile=tile)
    fb, cb = pairwise_ref(pos, cutoff=1.5)
    assert np.array_equal(np.asarray(ca), np.asarray(cb))
    assert rel_force_err(fa, fb) < 5e-3


def test_two_atoms_attract_and_repel():
    # r > 2^(1/6) sigma: attraction; r < 2^(1/6): repulsion.
    far = jnp.array([[0.0, 0.0, 0.0], [1.5, 0.0, 0.0]])
    f, _ = pairwise(far, cutoff=2.5)
    assert f[0, 0] > 0 and f[1, 0] < 0  # pulled toward each other
    near = jnp.array([[0.0, 0.0, 0.0], [0.9, 0.0, 0.0]])
    f, _ = pairwise(near, cutoff=2.5)
    assert f[0, 0] < 0 and f[1, 0] > 0  # pushed apart


def test_forces_sum_to_zero():
    """Newton's third law: total force is (numerically) zero."""
    pos = rand_pos(7, 80, 5.0)
    f, _ = pairwise(pos, cutoff=2.0)
    total = jnp.abs(jnp.sum(f, axis=0))
    fmax = jnp.max(jnp.abs(f)) + 1e-6
    assert float(jnp.max(total)) / float(fmax) < 1e-3


def test_coordination_on_lattice():
    # Simple cubic lattice with spacing 1.0, cutoff 1.1: interior atoms
    # have 6 neighbours, faces 5, edges 4, corners 3.
    g = np.stack(np.meshgrid(*[np.arange(4)] * 3, indexing="ij"),
                 -1).reshape(-1, 3).astype(np.float32)
    _, coord = pairwise(jnp.asarray(g), cutoff=1.1)
    coord = np.asarray(coord).reshape(4, 4, 4)
    assert coord[1, 1, 1] == 6
    assert coord[0, 1, 1] == 5
    assert coord[0, 0, 1] == 4
    assert coord[0, 0, 0] == 3


def test_isolated_atom_zero():
    pos = jnp.array([[0.0, 0.0, 0.0], [100.0, 100.0, 100.0]])
    f, c = pairwise(pos, cutoff=2.5)
    assert np.array_equal(np.asarray(c), [0.0, 0.0])
    assert float(jnp.max(jnp.abs(f))) == 0.0


def test_padding_does_not_leak():
    """n just below/above a tile boundary must agree with the oracle."""
    for n in (127, 128, 129):
        pos = rand_pos(n, n, 8.0)
        fk, ck = pairwise(pos, cutoff=1.5, tile=128)
        fr, cr = pairwise_ref(pos, cutoff=1.5)
        assert np.array_equal(np.asarray(ck), np.asarray(cr)), n
        assert rel_force_err(fk, fr) < 5e-3, n


def test_translation_invariance():
    pos = rand_pos(3, 50, 5.0)
    f0, c0 = pairwise(pos, cutoff=1.5)
    f1, c1 = pairwise(pos + 3.0, cutoff=1.5)
    assert np.array_equal(np.asarray(c0), np.asarray(c1))
    assert rel_force_err(f1, f0) < 5e-3
