# L2 model tests: physics sanity + fixed shapes for the AOT contract.

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def init_md(seed=0):
    """Jittered-lattice initial condition (no overlapping atoms)."""
    n_side = 16  # 16^3 = 4096 = model.N_ATOMS
    assert n_side ** 3 == model.N_ATOMS
    spacing = model.BOX / n_side
    ax = (np.arange(n_side) + 0.5) * spacing
    g = np.stack(np.meshgrid(ax, ax, ax, indexing="ij"), -1).reshape(-1, 3)
    jitter = jax.random.uniform(
        jax.random.PRNGKey(seed), g.shape, minval=-0.05, maxval=0.05)
    pos = jnp.asarray(g, jnp.float32) + jitter * spacing
    vel = 0.05 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                   g.shape, jnp.float32)
    return pos, vel


def test_md_step_shapes_and_finite():
    pos, vel = init_md()
    p1, v1 = model.md_step(pos, vel)
    assert p1.shape == (model.N_ATOMS, 3) and v1.shape == (model.N_ATOMS, 3)
    assert bool(jnp.all(jnp.isfinite(p1))) and bool(jnp.all(jnp.isfinite(v1)))
    assert float(jnp.min(p1)) >= 0.0 and float(jnp.max(p1)) < model.BOX


def test_md_step_advances_state():
    pos, vel = init_md()
    p1, v1 = model.md_step(pos, vel)
    assert float(jnp.max(jnp.abs(p1 - pos))) > 0.0


def test_md_stable_over_many_steps():
    pos, vel = init_md()
    for _ in range(5):  # 5 * MD_UNROLL leapfrog steps
        pos, vel = model.md_step(pos, vel)
    assert bool(jnp.all(jnp.isfinite(pos)))
    # Velocities should stay bounded (no explosion).
    assert float(jnp.max(jnp.abs(vel))) < 50.0


def test_detector_on_md_dump():
    pos, _ = init_md()
    stats = model.diamond_detector(pos)
    assert stats.shape == (4,)
    assert float(stats[3]) == model.N_ATOMS
    assert 0.0 <= float(stats[0]) <= model.N_ATOMS


def test_detector_counts_diamond_sites():
    # Hand-built cluster: center with exactly 4 neighbours at distance 1.
    pts = np.full((model.N_ATOMS, 3), 1e3, np.float32)
    pts += np.arange(model.N_ATOMS, dtype=np.float32)[:, None] * 10.0
    center = np.array([50.0, 50.0, 50.0], np.float32)
    tet = np.array([[1, 1, 1], [1, -1, -1], [-1, 1, -1], [-1, -1, 1]],
                   np.float32) / np.sqrt(3.0)
    pts[0] = center
    pts[1:5] = center + tet  # distance 1 < DIAMOND_CUTOFF
    stats = model.diamond_detector(jnp.asarray(pts))
    assert float(stats[0]) == 1.0  # only the center has coordination 4


def test_nyx_step_conserves_mass():
    den = jax.random.uniform(jax.random.PRNGKey(2),
                             (model.GRID,) * 3) + 0.5
    total0 = float(jnp.sum(den))
    for _ in range(10):
        den = model.nyx_step(den)
    assert bool(jnp.all(jnp.isfinite(den)))
    assert float(jnp.min(den)) >= 0.0
    np.testing.assert_allclose(float(jnp.sum(den)), total0, rtol=1e-4)


def test_nyx_step_grows_structure():
    """Overdensity growth: the density contrast must increase."""
    den = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(3),
                                        (model.GRID,) * 3)
    den = jnp.maximum(den, 0.1)
    c0 = float(jnp.std(den) / jnp.mean(den))
    for _ in range(20):
        den = model.nyx_step(den)
    c1 = float(jnp.std(den) / jnp.mean(den))
    assert c1 > c0


def test_halo_finder_shapes():
    den = jax.random.uniform(jax.random.PRNGKey(4), (model.GRID,) * 3)
    mask, stats = model.halo_finder(den, jnp.asarray([0.9], jnp.float32))
    assert mask.shape == (model.GRID,) * 3
    assert stats.shape == (4,)


def test_halo_finder_on_evolved_field():
    """End-to-end L2 physics: evolved field develops findable halos."""
    den = 1.0 + 0.2 * jax.random.normal(jax.random.PRNGKey(5),
                                        (model.GRID,) * 3)
    den = jnp.maximum(den, 0.05)
    for _ in range(15):
        den = model.nyx_step(den)
    thr = jnp.asarray([float(jnp.mean(den) + 2 * jnp.std(den))], jnp.float32)
    _, stats = model.halo_finder(den, thr)
    assert float(stats[0]) > 0.0  # clustering produced halos
