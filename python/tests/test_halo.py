# Pallas halo kernel vs pure-jnp oracle (Reeber proxy).

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import halo, halo_ref

SET = dict(deadline=None, max_examples=25)


def rand_density(seed, shape):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1),
       d=st.integers(2, 12), h=st.integers(2, 12), w=st.integers(2, 12),
       thr=st.floats(0.0, 1.0))
def test_kernel_matches_ref(seed, d, h, w, thr):
    den = rand_density(seed, (d, h, w))
    mk, sk = halo(den, thr)
    mr, sr = halo_ref(den, thr)
    assert np.array_equal(np.asarray(mk), np.asarray(mr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_single_peak():
    den = np.zeros((8, 8, 8), np.float32)
    den[4, 4, 4] = 5.0
    mask, stats = halo(jnp.asarray(den), 1.0)
    assert float(stats[0]) == 1.0          # one halo
    assert float(stats[1]) == 5.0          # its mass
    assert float(stats[2]) == 5.0          # peak density
    assert np.asarray(mask)[4, 4, 4] == 1.0
    assert float(jnp.sum(mask)) == 1.0


def test_two_separated_peaks():
    den = np.zeros((10, 10, 10), np.float32)
    den[2, 2, 2] = 3.0
    den[7, 7, 7] = 4.0
    _, stats = halo(jnp.asarray(den), 2.0)
    assert float(stats[0]) == 2.0
    assert float(stats[1]) == 7.0


def test_plateau_is_not_strict_max():
    # Two adjacent equal cells: neither strictly exceeds the other.
    den = np.zeros((6, 6, 6), np.float32)
    den[3, 3, 3] = 2.0
    den[3, 3, 4] = 2.0
    _, stats = halo(jnp.asarray(den), 1.0)
    assert float(stats[0]) == 0.0
    assert float(stats[1]) == 4.0  # mass still counted


def test_threshold_filters_peaks():
    den = np.zeros((6, 6, 6), np.float32)
    den[1, 1, 1] = 1.5
    den[4, 4, 4] = 3.5
    _, lo = halo(jnp.asarray(den), 1.0)
    _, hi = halo(jnp.asarray(den), 2.0)
    assert float(lo[0]) == 2.0
    assert float(hi[0]) == 1.0


def test_uniform_field_no_halos():
    den = jnp.full((5, 5, 5), 1.0)
    mask, stats = halo(den, 0.5)
    assert float(stats[0]) == 0.0
    assert float(jnp.sum(mask)) == 0.0
    assert float(stats[3]) == 1.0  # all above threshold


def test_corner_peak_counts():
    """Boundary cells can be halos (padding is -inf, not wrap)."""
    den = np.zeros((4, 4, 4), np.float32)
    den[0, 0, 0] = 9.0
    _, stats = halo(jnp.asarray(den), 1.0)
    assert float(stats[0]) == 1.0
