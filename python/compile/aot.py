# AOT lowering: JAX -> HLO *text* -> artifacts/.
#
# HLO text (not HloModuleProto.serialize()) is the interchange format:
# jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
# crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the XLA
# text parser reassigns ids, so text round-trips cleanly. See
# /opt/xla-example/gen_hlo.py.
#
# Besides the per-entry-point *.hlo.txt, this writes
# artifacts/manifest.tsv describing each executable's I/O signature so
# the Rust runtime can validate shapes at load time:
#
#   name \t input shapes (semicolon-joined "f32[4096,3]") \t output shapes

import argparse
import os

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _fmt_aval(aval) -> str:
    dtype = str(aval.dtype)
    short = {"float32": "f32", "float64": "f64", "int32": "s32",
             "uint64": "u64", "int64": "s64"}.get(dtype, dtype)
    return f"{short}[{','.join(str(d) for d in aval.shape)}]"


def signature(name):
    """(input_sig, output_sig) strings for the manifest."""
    fn, args = model.ENTRY_POINTS[name]
    low = model.lowered(name)
    in_sig = ";".join(_fmt_aval(a) for a in args)
    out_avals = low.out_info
    import jax
    flat, _ = jax.tree_util.tree_flatten(out_avals)
    out_sig = ";".join(_fmt_aval(a) for a in flat)
    return in_sig, out_sig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of entry points")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only or sorted(model.ENTRY_POINTS)
    rows = []
    for name in names:
        text = to_hlo_text(model.lowered(name))
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        in_sig, out_sig = signature(name)
        rows.append(f"{name}\t{in_sig}\t{out_sig}")
        print(f"wrote {path} ({len(text)} chars)  {in_sig} -> {out_sig}")

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(rows) + "\n")


if __name__ == "__main__":
    main()
