# L2: JAX compute graphs for the Wilkins task payloads.
#
# These are the science codes the Wilkins paper couples in its two use
# cases, rebuilt as fixed-shape jitted JAX functions (calling the L1
# Pallas kernels) and AOT-lowered once by aot.py. The Rust coordinator
# loads the resulting HLO text via PJRT and runs it on the request path;
# Python never runs at workflow time.
#
#   md_step          — LAMMPS proxy: leapfrog MD over N_ATOMS LJ atoms,
#                      MD_UNROLL inner steps fused per execution.
#   diamond_detector — feature detector: counts atoms whose coordination
#                      number matches the diamond lattice (4 neighbours
#                      within DIAMOND_CUTOFF).
#   nyx_step         — Nyx proxy: mass-conserving gravity-like evolution
#                      of a GRID^3 density field (diffusion + local
#                      overdensity growth).
#   halo_finder      — Reeber proxy: thresholded local-max halo finder
#                      over the density field (L1 `halo` kernel).
#
# Shape constants below are the single source of truth; aot.py writes
# them into artifacts/manifest.tsv for the Rust runtime.

import functools

import jax
import jax.numpy as jnp

from .kernels import halo, pairwise

# ---- materials-science use case (Sec. 4.2.1) -------------------------------
N_ATOMS = 4096          # paper: 4,360-atom water model; 4096 for tile align
BOX = 18.0              # LJ reduced units; density ~ 0.7
MD_DT = 0.002
MD_UNROLL = 10          # inner steps fused into one HLO execution
LJ_CUTOFF = 2.5
DIAMOND_CUTOFF = 1.3    # first-shell cutoff for coordination counting
DIAMOND_COORD = 4.0     # diamond lattice coordination number

# ---- cosmology use case (Sec. 4.2.2) ---------------------------------------
GRID = 64               # paper: 256^3 Nyx grid; 64^3 keeps VMEM-resident
NYX_KAPPA = 0.05        # diffusion strength (stability: < 1/6)
NYX_ALPHA = 0.15        # overdensity growth rate
NYX_DMAX = 8.0          # logistic carrying capacity (halts runaway spikes)


def md_step(pos, vel):
    """MD_UNROLL leapfrog (kick-drift) steps of LJ dynamics.

    pos, vel: (N_ATOMS, 3) f32. Positions wrap into [0, BOX). Forces are
    non-periodic (no minimum image) — a documented proxy simplification;
    the workflow only needs a producer with LAMMPS-like output cadence.
    """

    def body(carry, _):
        p, v = carry
        f, _ = pairwise(p, cutoff=LJ_CUTOFF)
        # Clip forces: the random initial condition can have close pairs.
        f = jnp.clip(f, -1e3, 1e3)
        v = v + MD_DT * f
        p = jnp.mod(p + MD_DT * v, BOX)
        return (p, v), None

    (pos, vel), _ = jax.lax.scan(body, (pos, vel), None, length=MD_UNROLL)
    return pos, vel


def diamond_detector(pos):
    """Diamond-structure statistics for one particle dump.

    Returns a (4,) f32 vector: [n_crystal, mean_coord, max_coord, n_atoms]
    where n_crystal counts atoms with exactly DIAMOND_COORD neighbours
    within DIAMOND_CUTOFF (the nucleation signal of Sec. 4.2.1).
    """
    _, coord = pairwise(pos, cutoff=DIAMOND_CUTOFF)
    ncry = jnp.sum((coord == DIAMOND_COORD).astype(jnp.float32))
    return jnp.stack([
        ncry,
        jnp.mean(coord),
        jnp.max(coord),
        jnp.asarray(float(pos.shape[0]), jnp.float32),
    ])


def nyx_step(density):
    """One mass-conserving evolution step of the (GRID,)*3 density field.

    Periodic 6-neighbour diffusion plus a logistic local growth term
    that amplifies overdensities (the gravity proxy) up to a carrying
    capacity NYX_DMAX, renormalised so total mass is exactly conserved.
    From white-noise initial conditions this develops hierarchical
    clustering (many small halos merging into fewer large ones) whose
    peaks the Reeber proxy finds.
    """
    d = density.astype(jnp.float32)
    nb = (jnp.roll(d, 1, 0) + jnp.roll(d, -1, 0)
          + jnp.roll(d, 1, 1) + jnp.roll(d, -1, 1)
          + jnp.roll(d, 1, 2) + jnp.roll(d, -1, 2))
    lap = nb - 6.0 * d
    grow = NYX_ALPHA * d * (d - jnp.mean(d)) * (1.0 - d / NYX_DMAX)
    grown = jnp.maximum(d + NYX_KAPPA * lap + grow, 0.0)
    # Renormalise to conserve total mass.
    total = jnp.sum(d)
    grown = grown * (total / jnp.maximum(jnp.sum(grown), 1e-12))
    return grown


def halo_finder(density, threshold):
    """Reeber proxy: halo mask + stats (see kernels.halo)."""
    mask, stats = halo(density, threshold)
    return mask, stats


# ---- AOT entry points (name -> (fn, example args)) --------------------------

def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


ENTRY_POINTS = {
    "md_step": (md_step, (_f32(N_ATOMS, 3), _f32(N_ATOMS, 3))),
    "diamond_detector": (diamond_detector, (_f32(N_ATOMS, 3),)),
    "nyx_step": (nyx_step, (_f32(GRID, GRID, GRID),)),
    "halo_finder": (halo_finder, (_f32(GRID, GRID, GRID), _f32(1))),
}


@functools.lru_cache(maxsize=None)
def lowered(name):
    """Lower an entry point; returns the jax Lowered object."""
    fn, args = ENTRY_POINTS[name]
    return jax.jit(fn).lower(*args)
