# L1: 3-D halo-finder Pallas kernel (Reeber proxy).
#
# The Wilkins paper's cosmology use case (Sec. 4.2.2) couples Nyx to
# Reeber, which finds "halos": regions of high dark-matter density. We
# proxy the merge-tree computation with its dominant primitive: a
# thresholded 6-neighbour local-maximum sweep fused with the mass
# reduction, done in a single pass over the density grid.
#
# TPU adaptation: the whole (D, H, W) grid is held in VMEM for the default
# 64^3 f32 case (1 MiB << 16 MiB VMEM), so the kernel is a single grid
# step; the stencil is expressed as shifted compares over a -inf-padded
# copy (vector unit), and the reductions fuse into the same pass. For
# grids beyond VMEM the documented schedule is z-slab BlockSpecs with a
# +-1 halo exchange performed by the caller (see DESIGN.md).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.0e38  # effectively -inf for f32 padding


def _halo_kernel(den_ref, thr_ref, mask_ref, stats_ref):
    d = den_ref[...]          # (D, H, W)
    t = thr_ref[0]

    p = jnp.pad(d, 1, constant_values=NEG)
    # Strict maximum over the 6 face neighbours.
    nmax = p[:-2, 1:-1, 1:-1]
    nmax = jnp.maximum(nmax, p[2:, 1:-1, 1:-1])
    nmax = jnp.maximum(nmax, p[1:-1, :-2, 1:-1])
    nmax = jnp.maximum(nmax, p[1:-1, 2:, 1:-1])
    nmax = jnp.maximum(nmax, p[1:-1, 1:-1, :-2])
    nmax = jnp.maximum(nmax, p[1:-1, 1:-1, 2:])

    above = d > t
    is_halo = above & (d > nmax)
    mask = is_halo.astype(jnp.float32)

    mask_ref[...] = mask
    stats_ref[0] = jnp.sum(mask)                          # halo count
    stats_ref[1] = jnp.sum(jnp.where(above, d, 0.0))      # mass above thr
    stats_ref[2] = jnp.max(d)                             # peak density
    stats_ref[3] = jnp.mean(above.astype(jnp.float32))    # volume fraction


@functools.partial(jax.jit, static_argnames=("interpret",))
def halo(density, threshold, *, interpret=True):
    """Halo mask and summary stats for a (D, H, W) f32 density grid.

    `threshold` is a scalar (or shape-(1,)) f32. Returns
    (mask (D,H,W) f32 in {0,1}, stats (4,) f32 =
     [count, mass_above, peak, vol_frac]).
    """
    den = density.astype(jnp.float32)
    thr = jnp.reshape(jnp.asarray(threshold, jnp.float32), (1,))
    mask, stats = pl.pallas_call(
        _halo_kernel,
        out_shape=[
            jax.ShapeDtypeStruct(den.shape, jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        ],
        interpret=interpret,
    )(den, thr)
    return mask, stats
