# L1: tiled pairwise-interaction Pallas kernel.
#
# Computes, in one pass over (row-tile, col-tile) blocks of the N x N
# interaction matrix:
#   * Lennard-Jones forces      F_i = sum_j f(r_ij) * (x_i - x_j)
#   * coordination numbers      c_i = |{ j != i : r_ij < cutoff }|
#
# This is the compute hot-spot of both the LAMMPS-proxy MD step and the
# diamond-structure feature detector (materials-science use case of the
# Wilkins paper, Sec. 4.2.1).
#
# TPU adaptation (DESIGN.md "Hardware adaptation"): squared distances are
# expressed as |x|^2 + |y|^2 - 2 x.y^T so the inner product maps onto the
# MXU; the force accumulation F = diag(rowsum(fmag)) @ x - fmag @ y is two
# more MXU contractions. The (TM, TN) tile lives in VMEM
# (TM*TN*4B + 2*TM*3*4B ~= 264 KiB for 256x256) and the j-axis of the grid
# accumulates into the output block, i.e. the classic "revisit the output
# block" Pallas reduction schedule. On CPU we run interpret=True only.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 128


def _pairwise_kernel(x_ref, y_ref, frc_ref, coord_ref, *, tm, tn,
                     cutoff2, sigma2, eps):
    i = pl.program_id(0)
    j = pl.program_id(1)

    x = x_ref[...]  # (TM, 3) row positions
    y = y_ref[...]  # (TN, 3) column positions

    # Squared distances via the MXU-friendly decomposition.
    xx = jnp.sum(x * x, axis=1, keepdims=True)        # (TM, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T      # (1, TN)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # (TM, TN) MXU
    d2 = xx + yy - 2.0 * xy

    # Mask self-interactions by global index; clamp to avoid 0-division.
    rows = i * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    cols = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    offdiag = rows != cols
    d2 = jnp.maximum(d2, 1e-12)
    within = offdiag & (d2 < cutoff2)

    # LJ force magnitude over r: f(r)/r = 24 eps (2 s6^2 - s6) / r^2,
    # with s6 = (sigma^2 / r^2)^3. Zeroed outside the cutoff.
    inv = sigma2 / d2
    s6 = inv * inv * inv
    fmag = jnp.where(within, 24.0 * eps * (2.0 * s6 * s6 - s6) / d2, 0.0)

    # F_i += rowsum(fmag) * x_i - fmag @ y   (second term is MXU again)
    rowsum = jnp.sum(fmag, axis=1, keepdims=True)             # (TM, 1)
    fblk = rowsum * x - jnp.dot(fmag, y, preferred_element_type=jnp.float32)
    cblk = jnp.sum(within.astype(jnp.float32), axis=1)        # (TM,)

    @pl.when(j == 0)
    def _init():
        frc_ref[...] = jnp.zeros_like(frc_ref)
        coord_ref[...] = jnp.zeros_like(coord_ref)

    frc_ref[...] += fblk
    coord_ref[...] += cblk


def _pad_positions(pos, npad):
    """Pad (n, 3) positions to (npad, 3) with mutually-distant sentinels.

    Sentinels sit on a 1e3-spaced ray far from the physical box, so
    sentinel-sentinel and sentinel-real distances always exceed any
    physically meaningful cutoff: padded rows contribute nothing to
    forces or coordination counts of real atoms.
    """
    n = pos.shape[0]
    if npad == n:
        return pos
    k = npad - n
    sx = 1e6 + jnp.arange(k, dtype=pos.dtype) * 1e3
    sentinel = jnp.stack([sx, jnp.zeros_like(sx), jnp.zeros_like(sx)], axis=1)
    return jnp.concatenate([pos, sentinel], axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("cutoff", "sigma", "eps", "tile", "interpret"))
def pairwise(pos, *, cutoff=2.5, sigma=1.0, eps=1.0, tile=DEFAULT_TILE,
             interpret=True):
    """Forces and coordination numbers for (n, 3) f32 positions.

    Returns (forces (n,3) f32, coord (n,) f32). `n` need not be a tile
    multiple; inputs are sentinel-padded and outputs sliced back.
    """
    n = pos.shape[0]
    npad = -(-n // tile) * tile
    x = _pad_positions(pos.astype(jnp.float32), npad)
    grid = (npad // tile, npad // tile)
    kern = functools.partial(
        _pairwise_kernel, tm=tile, tn=tile,
        cutoff2=float(cutoff) ** 2, sigma2=float(sigma) ** 2, eps=float(eps))
    frc, coord = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, 3), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((tile,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, 3), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=interpret,
    )(x, x)
    return frc[:n], coord[:n]
