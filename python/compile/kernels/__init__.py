# L1 Pallas kernels (build-time only; lowered into the L2 HLO).
from .halo import halo
from .pairwise import pairwise
from .ref import halo_ref, pairwise_ref

__all__ = ["halo", "pairwise", "halo_ref", "pairwise_ref"]
