# Pure-jnp correctness oracles for the Pallas kernels.
#
# These are the ground truth the pytest/hypothesis suites compare the
# tiled kernels against. They are written for clarity, not speed: full
# O(N^2) matrices, no tiling, no padding tricks.

import jax.numpy as jnp


def pairwise_ref(pos, *, cutoff=2.5, sigma=1.0, eps=1.0):
    """Reference LJ forces + coordination numbers for (n, 3) positions."""
    pos = pos.astype(jnp.float32)
    n = pos.shape[0]
    diff = pos[:, None, :] - pos[None, :, :]          # (n, n, 3)
    d2 = jnp.sum(diff * diff, axis=-1)                # (n, n)
    offdiag = ~jnp.eye(n, dtype=bool)
    d2c = jnp.maximum(d2, 1e-12)
    within = offdiag & (d2c < cutoff ** 2)

    inv = (sigma ** 2) / d2c
    s6 = inv ** 3
    fmag = jnp.where(within, 24.0 * eps * (2.0 * s6 * s6 - s6) / d2c, 0.0)
    forces = jnp.sum(fmag[:, :, None] * diff, axis=1)  # (n, 3)
    coord = jnp.sum(within.astype(jnp.float32), axis=1)
    return forces, coord


def halo_ref(density, threshold):
    """Reference thresholded 6-neighbour local-maximum halo finder."""
    d = density.astype(jnp.float32)
    t = jnp.asarray(threshold, jnp.float32).reshape(())
    neg = -3.0e38
    p = jnp.pad(d, 1, constant_values=neg)
    nmax = p[:-2, 1:-1, 1:-1]
    for sl in (p[2:, 1:-1, 1:-1], p[1:-1, :-2, 1:-1], p[1:-1, 2:, 1:-1],
               p[1:-1, 1:-1, :-2], p[1:-1, 1:-1, 2:]):
        nmax = jnp.maximum(nmax, sl)
    above = d > t
    mask = (above & (d > nmax)).astype(jnp.float32)
    stats = jnp.stack([
        jnp.sum(mask),
        jnp.sum(jnp.where(above, d, 0.0)),
        jnp.max(d),
        jnp.mean(above.astype(jnp.float32)),
    ])
    return mask, stats
